//! Recursive-descent parser for gin values (python-literal flavored).

use super::Value;

#[derive(Debug, thiserror::Error)]
#[error("value parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

pub fn parse_value(text: &str) -> Result<Value, ParseError> {
    let mut p = P { b: text.as_bytes(), pos: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct P<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> P<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.into() }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len() && self.b[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'\'') | Some(b'"') => self.string(),
            Some(b'[') => self.list(),
            Some(b'(') => self.list(), // tuples parse as lists
            Some(b'{') => self.dict(),
            Some(b'@') => {
                self.pos += 1;
                Ok(Value::Reference(self.ident_path()?))
            }
            Some(b'%') => {
                self.pos += 1;
                Ok(Value::Macro(self.ident_path()?))
            }
            Some(c) if c == b'-' || c == b'+' || c.is_ascii_digit() => self.number(),
            Some(_) => self.keyword(),
            None => Err(self.err("empty value")),
        }
    }

    fn keyword(&mut self) -> Result<Value, ParseError> {
        let id = self.ident_path()?;
        match id.as_str() {
            "True" | "true" => Ok(Value::Bool(true)),
            "False" | "false" => Ok(Value::Bool(false)),
            "None" | "none" => Ok(Value::None),
            // Bare identifiers are treated as strings (t5x config convenience).
            _ => Ok(Value::Str(id)),
        }
    }

    fn ident_path(&mut self) -> Result<String, ParseError> {
        let start = self.pos;
        while matches!(self.peek(), Some(c) if c.is_ascii_alphanumeric() || matches!(c, b'_' | b'.' | b'/' | b'-'))
        {
            self.pos += 1;
        }
        if self.pos == start {
            return Err(self.err("expected identifier"));
        }
        Ok(std::str::from_utf8(&self.b[start..self.pos]).unwrap().to_string())
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if matches!(self.peek(), Some(b'-') | Some(b'+')) {
            self.pos += 1;
        }
        let mut is_float = false;
        while let Some(c) = self.peek() {
            match c {
                b'0'..=b'9' | b'_' => self.pos += 1,
                b'.' | b'e' | b'E' => {
                    is_float = true;
                    self.pos += 1;
                    if matches!(self.peek(), Some(b'-') | Some(b'+')) {
                        self.pos += 1;
                    }
                }
                _ => break,
            }
        }
        let s: String = std::str::from_utf8(&self.b[start..self.pos])
            .unwrap()
            .replace('_', "");
        if is_float {
            s.parse::<f64>().map(Value::Float).map_err(|_| self.err("bad float"))
        } else {
            s.parse::<i64>().map(Value::Int).map_err(|_| self.err("bad int"))
        }
    }

    fn string(&mut self) -> Result<Value, ParseError> {
        let quote = self.b[self.pos];
        self.pos += 1;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(c) if c == quote => {
                    self.pos += 1;
                    return Ok(Value::Str(out));
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(c) => out.push(c as char),
                        None => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn list(&mut self) -> Result<Value, ParseError> {
        let close = if self.b[self.pos] == b'[' { b']' } else { b')' };
        self.pos += 1;
        let mut items = Vec::new();
        loop {
            self.ws();
            if self.peek() == Some(close) {
                self.pos += 1;
                return Ok(Value::List(items));
            }
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(c) if c == close => {}
                _ => return Err(self.err("expected ',' or close bracket")),
            }
        }
    }

    fn dict(&mut self) -> Result<Value, ParseError> {
        self.pos += 1;
        let mut kv = Vec::new();
        loop {
            self.ws();
            if self.peek() == Some(b'}') {
                self.pos += 1;
                return Ok(Value::Dict(kv));
            }
            let key = match self.value()? {
                Value::Str(s) => s,
                other => format!("{other:?}"),
            };
            self.ws();
            if self.peek() != Some(b':') {
                return Err(self.err("expected ':'"));
            }
            self.pos += 1;
            self.ws();
            let val = self.value()?;
            kv.push((key, val));
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {}
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numbers() {
        assert_eq!(parse_value("42").unwrap(), Value::Int(42));
        assert_eq!(parse_value("-3").unwrap(), Value::Int(-3));
        assert_eq!(parse_value("1e-3").unwrap(), Value::Float(1e-3));
        assert_eq!(parse_value("2.5").unwrap(), Value::Float(2.5));
        assert_eq!(parse_value("1_000").unwrap(), Value::Int(1000));
    }

    #[test]
    fn strings_refs_macros() {
        assert_eq!(parse_value("'abc'").unwrap(), Value::Str("abc".into()));
        assert_eq!(
            parse_value("@scope/fn").unwrap(),
            Value::Reference("scope/fn".into())
        );
        assert_eq!(parse_value("%BATCH").unwrap(), Value::Macro("BATCH".into()));
    }

    #[test]
    fn nested_structures() {
        let v = parse_value("[1, [2, 3], {'a': True}, None]").unwrap();
        match v {
            Value::List(items) => {
                assert_eq!(items.len(), 4);
                assert_eq!(items[3], Value::None);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn tuples_as_lists() {
        assert_eq!(
            parse_value("(1, 2)").unwrap(),
            Value::List(vec![Value::Int(1), Value::Int(2)])
        );
    }

    #[test]
    fn errors() {
        assert!(parse_value("[1,").is_err());
        assert!(parse_value("'unterminated").is_err());
        assert!(parse_value("1 2").is_err());
    }
}
