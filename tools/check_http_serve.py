#!/usr/bin/env python3
"""Smoke-test the ``t5x serve`` HTTP gateway (stdlib only; the CI
oracle for the PR-8 serving front end).

Drives a live server through its whole surface:

* polls ``GET /healthz`` until the server is up (``--startup-timeout``);
* fires ``--requests`` concurrent ``POST /v1/generate`` bodies and
  validates every 200 response's JSON schema (``id`` echoed, non-empty
  ``tokens`` list of ints, ``text`` string, numeric ``queue_ms`` /
  ``latency_ms``, and ``ttft_ms`` when present);
* hits ``/healthz`` and ``/metrics`` *during* the load and checks the
  metrics document's shape (counters / histograms_ms / queue / replicas);
* with ``--expect-429``, sends the burst without staggering against a
  tiny admission queue and requires at least one 429 carrying a
  ``Retry-After`` header (backpressure must be explicit, never a hang);
* with ``--chaos-request ID``, first sends a request whose id matches a
  server-side armed ``replica_panic`` fault and requires an *explicit*
  500 + JSON error (never a hang or dropped connection), then with
  ``--expect-degraded`` polls ``/healthz`` until it reports
  ``degraded`` with a per-replica ``down`` entry — the surviving
  replicas must still answer the ``--requests`` phase afterwards;
* with ``--drain``, finishes by POSTing ``/admin/drain`` and expects
  the server to answer 200 ``{"status": "draining"}``.

Usage (CI):

    python tools/check_http_serve.py --port 8077 --requests 8 --drain
    python tools/check_http_serve.py --port 8078 --burst 16 --gen 24 \
        --expect-429 --drain
    python tools/check_http_serve.py --port 8079 --requests 4 \
        --chaos-request 999 --expect-degraded --drain

Exit status is non-zero on any violation, one line per problem on
stderr.
"""

import argparse
import http.client
import json
import sys
import threading
import time


def request(host, port, method, path, body=None, timeout=30.0):
    """One HTTP round-trip; returns (status, headers_dict, parsed_json)."""
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    try:
        payload = None
        headers = {}
        if body is not None:
            payload = json.dumps(body).encode("utf-8")
            headers["Content-Type"] = "application/json"
        conn.request(method, path, body=payload, headers=headers)
        resp = conn.getresponse()
        raw = resp.read()
        try:
            doc = json.loads(raw.decode("utf-8")) if raw else None
        except (ValueError, UnicodeDecodeError):
            doc = None
        return resp.status, dict(resp.getheaders()), doc
    finally:
        conn.close()


def wait_healthy(host, port, timeout_s):
    deadline = time.monotonic() + timeout_s
    last = None
    while time.monotonic() < deadline:
        try:
            status, _, doc = request(host, port, "GET", "/healthz", timeout=2.0)
            if status == 200 and isinstance(doc, dict):
                return doc
            last = f"status {status}"
        except OSError as e:
            last = str(e)
        time.sleep(0.2)
    raise RuntimeError(f"server on {host}:{port} never became healthy ({last})")


def check_generate_response(errors, i, status, headers, doc, expect_id):
    if status != 200:
        errors.append(f"request {i}: expected 200, got {status} ({doc})")
        return
    if not isinstance(doc, dict):
        errors.append(f"request {i}: 200 with non-JSON body")
        return
    if doc.get("id") != expect_id:
        errors.append(f"request {i}: id {doc.get('id')!r} != sent {expect_id}")
    tokens = doc.get("tokens")
    if (not isinstance(tokens, list) or not tokens
            or not all(isinstance(t, (int, float)) for t in tokens)):
        errors.append(f"request {i}: bad 'tokens' {tokens!r}")
    if not isinstance(doc.get("text"), str):
        errors.append(f"request {i}: missing 'text' string")
    for field in ("queue_ms", "latency_ms"):
        if not isinstance(doc.get(field), (int, float)):
            errors.append(f"request {i}: missing numeric '{field}'")
    if "ttft_ms" in doc and not isinstance(doc["ttft_ms"], (int, float)):
        errors.append(f"request {i}: non-numeric 'ttft_ms'")
    ctype = {k.lower(): v for k, v in headers.items()}.get("content-type", "")
    if "application/json" not in ctype:
        errors.append(f"request {i}: Content-Type {ctype!r}")


def run_concurrent(host, port, n, gen, errors):
    """n staggered concurrent generate calls; every one must return 200.

    The stagger (25 ms apart) keeps this phase meaningful against a tiny
    admission queue too: the router drains a submitted request into a
    free engine slot within microseconds, so spaced arrivals never trip
    backpressure — the unstaggered collision test is ``run_burst``.
    """
    results = [None] * n

    def one(i):
        time.sleep(0.025 * i)
        body = {"id": i + 1, "prompt": [5 + i, 9, 11], "max_tokens": gen}
        try:
            results[i] = request(host, port, "POST", "/v1/generate", body)
        except OSError as e:
            results[i] = e

    threads = [threading.Thread(target=one, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    # Health + metrics must answer while generate load is in flight.
    try:
        status, _, doc = request(host, port, "GET", "/healthz", timeout=10.0)
        if status != 200 or not isinstance(doc, dict) or "status" not in doc:
            errors.append(f"/healthz under load: status {status}, {doc}")
        status, _, doc = request(host, port, "GET", "/metrics", timeout=10.0)
        if status != 200 or not isinstance(doc, dict):
            errors.append(f"/metrics under load: status {status}")
        else:
            for section in ("counters", "histograms_ms", "queue", "replicas"):
                if section not in doc:
                    errors.append(f"/metrics missing '{section}'")
    except OSError as e:
        errors.append(f"health/metrics under load: {e}")
    for t in threads:
        t.join()
    for i, r in enumerate(results):
        if isinstance(r, Exception) or r is None:
            errors.append(f"request {i}: transport error {r!r}")
        else:
            status, headers, doc = r
            check_generate_response(errors, i, status, headers, doc, i + 1)


def run_burst(host, port, n, gen, errors):
    """Unstaggered burst against a tiny queue: some 200s, some 429s —
    and every 429 must carry Retry-After. Zero 429s means admission
    control never engaged (gate failure)."""
    results = [None] * n

    def one(i):
        body = {"id": 100 + i, "prompt": [7, 3, i % 32 + 2], "max_tokens": gen}
        try:
            results[i] = request(host, port, "POST", "/v1/generate", body)
        except OSError as e:
            results[i] = e

    threads = [threading.Thread(target=one, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    seen = {"ok": 0, "rejected": 0}
    for i, r in enumerate(results):
        if isinstance(r, Exception) or r is None:
            errors.append(f"burst {i}: transport error {r!r}")
            continue
        status, headers, doc = r
        if status == 200:
            seen["ok"] += 1
            check_generate_response(errors, i, status, headers, doc, 100 + i)
        elif status == 429:
            seen["rejected"] += 1
            retry = {k.lower(): v for k, v in headers.items()}.get("retry-after")
            if retry is None:
                errors.append(f"burst {i}: 429 without Retry-After")
            if not isinstance(doc, dict) or "error" not in doc:
                errors.append(f"burst {i}: 429 without JSON error body")
        else:
            errors.append(f"burst {i}: unexpected status {status} ({doc})")
    if seen["rejected"] == 0:
        errors.append(
            f"burst of {n}: no 429 seen ({seen['ok']} x 200) — "
            "admission backpressure never engaged"
        )
    return seen


def run_chaos(host, port, chaos_id, gen, errors):
    """One request armed (server-side, via --fault-plan) to kill the
    replica that dispatches it. The dying replica flushes its in-flight
    table before unwinding, so the reply must be an explicit 500 with a
    JSON error body — never a hang or a dropped connection."""
    try:
        body = {"id": chaos_id, "prompt": [5, 9, 11], "max_tokens": gen}
        status, _, doc = request(host, port, "POST", "/v1/generate", body)
        if status != 500:
            errors.append(
                f"chaos request {chaos_id}: expected 500, got {status} ({doc})")
        elif not isinstance(doc, dict) or "error" not in doc:
            errors.append(
                f"chaos request {chaos_id}: 500 without JSON error body ({doc})")
        else:
            print(f"chaos request {chaos_id}: failed explicitly "
                  f"({doc['error']!r})")
    except OSError as e:
        errors.append(f"chaos request {chaos_id}: transport error {e}")


def wait_degraded(host, port, timeout_s, errors):
    """Poll /healthz until it reports the replica death: status
    'degraded', replicas_alive < replicas, and per_replica carrying both
    a 'down' and an 'up' entry."""
    deadline = time.monotonic() + timeout_s
    last = None
    while time.monotonic() < deadline:
        try:
            status, _, doc = request(host, port, "GET", "/healthz",
                                     timeout=2.0)
            if status == 200 and isinstance(doc, dict) \
                    and doc.get("status") == "degraded":
                alive = doc.get("replicas_alive")
                total = doc.get("replicas")
                states = [r.get("state")
                          for r in doc.get("per_replica", [])]
                if not (isinstance(alive, (int, float))
                        and isinstance(total, (int, float))
                        and alive < total):
                    errors.append(f"degraded healthz with bad counts: {doc}")
                if "down" not in states or "up" not in states:
                    errors.append(f"degraded healthz per_replica: {states}")
                print(f"healthz degraded: {alive}/{total} replicas alive")
                return
            last = doc if status == 200 else f"status {status}"
        except OSError as e:
            last = str(e)
        time.sleep(0.2)
    errors.append(f"healthz never reported 'degraded' (last: {last})")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--requests", type=int, default=8,
                    help="concurrent generate calls that must all return 200")
    ap.add_argument("--burst", type=int, default=0,
                    help="extra unstaggered burst size (use with --expect-429)")
    ap.add_argument("--gen", type=int, default=8, help="max_tokens per request")
    ap.add_argument("--expect-429", action="store_true",
                    help="require at least one 429 (+Retry-After) in the burst")
    ap.add_argument("--chaos-request", type=int, default=0,
                    help="send this request id first and require an "
                         "explicit 500 (pairs with a server-side "
                         "replica_panic fault plan)")
    ap.add_argument("--expect-degraded", action="store_true",
                    help="after the chaos request, poll /healthz until "
                         "it reports 'degraded'")
    ap.add_argument("--drain", action="store_true",
                    help="POST /admin/drain at the end")
    ap.add_argument("--startup-timeout", type=float, default=60.0)
    args = ap.parse_args()

    errors = []
    try:
        health = wait_healthy(args.host, args.port, args.startup_timeout)
    except RuntimeError as e:
        print(f"check_http_serve: FAIL — {e}", file=sys.stderr)
        return 1
    print(f"healthy: {health}")

    if args.chaos_request:
        run_chaos(args.host, args.port, args.chaos_request, args.gen, errors)
        if args.expect_degraded:
            wait_degraded(args.host, args.port, 15.0, errors)

    if args.requests > 0:
        run_concurrent(args.host, args.port, args.requests, args.gen, errors)
        print(f"{args.requests} concurrent generate call(s) done")

    if args.burst > 0:
        seen = run_burst(args.host, args.port, args.burst, args.gen, errors)
        print(f"burst of {args.burst}: {seen['ok']} x 200, "
              f"{seen['rejected']} x 429")
        if not args.expect_429:
            # Burst without --expect-429: drop the zero-429 complaint.
            errors[:] = [e for e in errors
                         if "backpressure never engaged" not in e]

    # Malformed body must be a 400, not a hang or a 500.
    try:
        status, _, doc = request(args.host, args.port, "POST", "/v1/generate",
                                 {"max_tokens": 4})
        if status != 400:
            errors.append(f"missing-prompt body: expected 400, got {status}")
        elif not isinstance(doc, dict) or "error" not in doc:
            errors.append("missing-prompt 400 without JSON error body")
    except OSError as e:
        errors.append(f"malformed-body probe: {e}")

    if args.drain:
        try:
            status, _, doc = request(args.host, args.port, "POST",
                                     "/admin/drain")
            if status != 200 or not isinstance(doc, dict) \
                    or doc.get("status") != "draining":
                errors.append(f"/admin/drain: status {status}, {doc}")
            else:
                print("drain requested")
        except OSError as e:
            errors.append(f"/admin/drain: {e}")

    if errors:
        for e in errors:
            print(f"check_http_serve: FAIL — {e}", file=sys.stderr)
        return 1
    print("check_http_serve: ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
