//! Dataset pipeline op graph — the `tensorflow.data` substitute that
//! seqio pipelines are assembled from. Pull-based, lazily evaluated,
//! deterministic when seeded, with threaded prefetch and order-preserving
//! parallel preprocessing for the infeed path.
//!
//! Unlike a chain of opaque iterator combinators, every stage is a
//! [`PipelineOp`]: it can report its position/buffers as a JSON
//! [`PipelineState`] and be restored from one, so iterator state is a
//! first-class checkpointed artifact (t5x's checkpointable-iterator
//! design, paper §3.2 Recoverability).
//!
//! ## State & restore contract
//!
//! `Dataset::state()` captures the full op-graph state; `Dataset::restore`
//! applies it to a *freshly built, structurally identical* pipeline (same
//! constructors, same seeds, same closure logic). After a restore, the
//! stream continues with exactly the examples an uninterrupted stream
//! would have produced next. Closures passed to `map`/`filter`/... must be
//! pure functions of their arguments (plus, for `enumerate_map`, the
//! element index) — hidden mutable closure state cannot be checkpointed.
//!
//! Ops with positional state (sources, `take`, `skip`, `enumerate_map`,
//! the deterministic cache reader) restore in O(1); buffering ops
//! (`shuffle_window`, `flat_map`, `parallel_map`) serialize their buffered
//! examples — `parallel_map` snapshots *incrementally*, serializing its
//! still-in-flight inputs instead of waiting for workers to drain;
//! `Dataset::new` over an arbitrary iterator records the number of
//! consumed elements and restores by replaying (deterministic streams
//! make replay exact).

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex};

use super::{deserialize_example, serialize_example, Example};
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::threads::{Pipe, PipeReceiver, PipeSender};

/// Legacy alias kept for downstream code that boxes example iterators.
pub type BoxIter = Box<dyn Iterator<Item = Example> + Send>;

/// One stage of a dataset pipeline: an iterator whose position (and any
/// internal buffers) can be captured and restored.
pub trait PipelineOp: Send {
    fn next(&mut self) -> Option<Example>;
    /// Capture this op's state (including all upstream ops). Takes `&mut`
    /// because buffering ops may need to quiesce in-flight work first.
    fn state(&mut self) -> Json;
    /// Restore a freshly built op to the captured position. Fails if the
    /// state was captured from a structurally different pipeline.
    fn restore(&mut self, state: &Json) -> anyhow::Result<()>;
}

/// Serialized pipeline position, persisted alongside model checkpoints.
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineState(pub Json);

impl PipelineState {
    pub fn to_json_string(&self) -> String {
        self.0.to_string()
    }

    pub fn parse(text: &str) -> anyhow::Result<PipelineState> {
        Ok(PipelineState(Json::parse(text)?))
    }
}

// ---------------------------------------------------------------------------
// state (de)serialization helpers
// ---------------------------------------------------------------------------

pub(crate) fn check_tag(s: &Json, tag: &str) -> anyhow::Result<()> {
    let got = s.get("op").and_then(|v| v.as_str()).unwrap_or("<missing>");
    anyhow::ensure!(
        got == tag,
        "pipeline state mismatch: expected op '{tag}', found '{got}'"
    );
    Ok(())
}

pub(crate) fn field<'a>(s: &'a Json, key: &str) -> anyhow::Result<&'a Json> {
    s.get(key)
        .ok_or_else(|| anyhow::anyhow!("pipeline state missing field '{key}'"))
}

pub(crate) fn field_usize(s: &Json, key: &str) -> anyhow::Result<usize> {
    field(s, key)?
        .as_usize()
        .ok_or_else(|| anyhow::anyhow!("pipeline state field '{key}' is not a number"))
}

pub(crate) fn field_bool(s: &Json, key: &str) -> anyhow::Result<bool> {
    field(s, key)?
        .as_bool()
        .ok_or_else(|| anyhow::anyhow!("pipeline state field '{key}' is not a bool"))
}

pub(crate) fn field_arr<'a>(s: &'a Json, key: &str) -> anyhow::Result<&'a [Json]> {
    field(s, key)?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("pipeline state field '{key}' is not an array"))
}

/// u64 values are serialized as hex strings: JSON numbers are f64 and
/// cannot hold a full 64-bit RNG state losslessly.
pub(crate) fn u64_to_json(v: u64) -> Json {
    Json::Str(format!("{v:016x}"))
}

pub(crate) fn u64_from_json(v: &Json) -> anyhow::Result<u64> {
    let s = v
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("expected hex string in pipeline state"))?;
    u64::from_str_radix(s, 16).map_err(|e| anyhow::anyhow!("bad hex u64 '{s}': {e}"))
}

pub(crate) fn rng_to_json(rng: &Pcg64) -> Json {
    let (state, inc) = rng.raw_state();
    Json::Arr(vec![u64_to_json(state), u64_to_json(inc)])
}

pub(crate) fn rng_from_json(v: &Json) -> anyhow::Result<Pcg64> {
    let a = v
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("expected [state, inc] rng pair"))?;
    anyhow::ensure!(a.len() == 2, "rng state must have two lanes");
    Ok(Pcg64::from_raw_state(u64_from_json(&a[0])?, u64_from_json(&a[1])?))
}

/// Buffered examples are embedded in state as hex of the binary record
/// encoding (compact, exact, and JSON-safe).
pub(crate) fn example_to_json(ex: &Example) -> Json {
    let bytes = serialize_example(ex);
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        use std::fmt::Write;
        let _ = write!(s, "{b:02x}");
    }
    Json::Str(s)
}

pub(crate) fn example_from_json(v: &Json) -> anyhow::Result<Example> {
    let s = v
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("expected hex-encoded example"))?;
    // ASCII guard keeps the byte-indexed slicing below panic-free on
    // malformed (e.g. hand-edited) state strings.
    anyhow::ensure!(s.is_ascii(), "non-ascii hex example");
    anyhow::ensure!(s.len() % 2 == 0, "odd-length hex example");
    let bytes: Result<Vec<u8>, _> = (0..s.len() / 2)
        .map(|i| u8::from_str_radix(&s[2 * i..2 * i + 2], 16))
        .collect();
    let bytes = bytes.map_err(|e| anyhow::anyhow!("bad hex example: {e}"))?;
    Ok(deserialize_example(&bytes)?)
}

fn examples_to_json<'a>(exs: impl Iterator<Item = &'a Example>) -> Json {
    Json::Arr(exs.map(example_to_json).collect())
}

fn examples_from_json(v: &[Json]) -> anyhow::Result<Vec<Example>> {
    v.iter().map(example_from_json).collect()
}

// ---------------------------------------------------------------------------
// Dataset: the public handle over the op graph
// ---------------------------------------------------------------------------

/// A lazily-evaluated, checkpointable stream of [`Example`]s.
pub struct Dataset {
    op: Box<dyn PipelineOp>,
}

impl Iterator for Dataset {
    type Item = Example;

    fn next(&mut self) -> Option<Example> {
        self.op.next()
    }
}

impl Dataset {
    /// Wrap an explicit [`PipelineOp`] (the constructor stateful sources
    /// like the deterministic cache reader use).
    pub fn from_op(op: impl PipelineOp + 'static) -> Dataset {
        Dataset { op: Box::new(op) }
    }

    /// Unwrap into the underlying op (for ops that compose datasets).
    pub fn into_op(self) -> Box<dyn PipelineOp> {
        self.op
    }

    /// Wrap an arbitrary iterator. Its state is the count of consumed
    /// elements; restore replays that many elements, which is exact for
    /// the deterministic streams seqio pipelines are built from.
    pub fn new(iter: impl Iterator<Item = Example> + Send + 'static) -> Dataset {
        Dataset::from_op(OpaqueIter { iter: Box::new(iter), pos: 0, done: false })
    }

    pub fn from_vec(v: Vec<Example>) -> Dataset {
        Dataset::from_op(VecSource { items: v, pos: 0 })
    }

    /// Capture the full pipeline position. Parallel stages snapshot
    /// incrementally (in-flight inputs are serialized, not drained).
    pub fn state(&mut self) -> PipelineState {
        PipelineState(self.op.state())
    }

    /// Reposition a freshly built, structurally identical pipeline to a
    /// captured state.
    pub fn restore(&mut self, state: &PipelineState) -> anyhow::Result<()> {
        self.op.restore(&state.0)
    }

    pub fn map<F>(self, f: F) -> Dataset
    where
        F: FnMut(Example) -> Example + Send + 'static,
    {
        Dataset::from_op(MapOp { inner: self.op, f: Box::new(f) })
    }

    pub fn filter<F>(self, f: F) -> Dataset
    where
        F: FnMut(&Example) -> bool + Send + 'static,
    {
        Dataset::from_op(FilterOp { inner: self.op, f: Box::new(f) })
    }

    pub fn flat_map<F>(self, f: F) -> Dataset
    where
        F: FnMut(Example) -> Vec<Example> + Send + 'static,
    {
        Dataset::from_op(FlatMapOp {
            inner: self.op,
            f: Box::new(f),
            pending: VecDeque::new(),
        })
    }

    /// Stamp each example with a per-example seed derived from `seed` and
    /// the example's position — how seqio gives stochastic preprocessors
    /// (e.g. span corruption) reproducible randomness.
    pub fn enumerate_map<F>(self, f: F) -> Dataset
    where
        F: FnMut(usize, Example) -> Example + Send + 'static,
    {
        Dataset::from_op(EnumerateMapOp { inner: self.op, f: Box::new(f), idx: 0 })
    }

    /// Order-preserving parallel map (tf.data `num_parallel_calls`
    /// semantics): `f` runs on up to `workers` background threads, but the
    /// output order is byte-identical to serial `map` regardless of worker
    /// scheduling. `f` must be pure — it may run ahead of the consumer and
    /// results are re-sequenced by input index.
    pub fn parallel_map<F>(self, f: F, workers: usize) -> Dataset
    where
        F: Fn(Example) -> Example + Send + Sync + 'static,
    {
        let workers = workers.max(1);
        Dataset::from_op(ParallelMapOp {
            inner: self.op,
            f: Arc::new(f),
            workers,
            capacity: (workers as u64) * 2,
            started: false,
            work_tx: None,
            result_rx: None,
            next_dispatch: 0,
            next_emit: 0,
            reorder: BTreeMap::new(),
            pending_inputs: BTreeMap::new(),
            replay: VecDeque::new(),
            inner_done: false,
        })
    }

    pub fn take(self, n: usize) -> Dataset {
        Dataset::from_op(TakeOp { inner: self.op, remaining: n })
    }

    pub fn skip(self, n: usize) -> Dataset {
        Dataset::from_op(SkipOp { inner: self.op, n, done: false })
    }

    /// Windowed shuffle (tf.data.shuffle semantics): fill a buffer of
    /// `window` elements once, then emit a uniformly random element and
    /// refill exactly one per `next()`. After the upstream ends the buffer
    /// drains without polling the upstream again.
    pub fn shuffle_window(self, window: usize, seed: u64) -> Dataset {
        Dataset::from_op(ShuffleOp {
            inner: self.op,
            buf: Vec::new(),
            rng: Pcg64::new(seed),
            window: window.max(1),
            primed: false,
            exhausted: false,
        })
    }

    /// Round-robin interleave of several datasets (used by file readers).
    pub fn interleave(parts: Vec<Dataset>) -> Dataset {
        Dataset::from_op(InterleaveOp {
            parts: parts.into_iter().map(|d| d.op).collect(),
            next: 0,
        })
    }

    /// Move production to a background thread with a bounded buffer —
    /// the infeed prefetch that hides data-pipeline latency (E9).
    ///
    /// Snapshots are **on-request**: steady-state production does zero
    /// state serialization (the old per-element upstream snapshot — one
    /// JSON build per element, quiescing an upstream `parallel_map` per
    /// element — was a documented anti-pattern). `state()` posts a
    /// snapshot request to the producer and drains in-transit elements
    /// into a parked queue until the reply arrives through the same
    /// channel, so the captured state is the upstream position after
    /// every parked/delivered element and the snapshot serializes those
    /// parked elements (at most `buffer` of them) alongside it. Restore
    /// repositions the upstream and replays the parked elements first —
    /// state is exact wherever it is taken (the infeed takes it at batch
    /// boundaries), and `prefetch` may now sit directly downstream of
    /// `parallel_map` or a large `shuffle_window`.
    pub fn prefetch(self, buffer: usize) -> Dataset {
        Dataset::from_op(PrefetchOp {
            pending: Some(self.op),
            buffer: buffer.max(1),
            rx: None,
            snap_tx: None,
            parked: VecDeque::new(),
            final_state: None,
            done: false,
        })
    }

    pub fn collect_vec(self) -> Vec<Example> {
        self.collect()
    }
}

// ---------------------------------------------------------------------------
// source ops
// ---------------------------------------------------------------------------

struct VecSource {
    items: Vec<Example>,
    pos: usize,
}

impl PipelineOp for VecSource {
    fn next(&mut self) -> Option<Example> {
        let e = self.items.get(self.pos).cloned();
        if e.is_some() {
            self.pos += 1;
        }
        e
    }

    fn state(&mut self) -> Json {
        Json::obj(vec![("op", Json::str("vec")), ("pos", Json::num(self.pos as f64))])
    }

    fn restore(&mut self, s: &Json) -> anyhow::Result<()> {
        check_tag(s, "vec")?;
        let pos = field_usize(s, "pos")?;
        anyhow::ensure!(
            pos <= self.items.len(),
            "saved position {pos} exceeds vec source length {}",
            self.items.len()
        );
        self.pos = pos;
        Ok(())
    }
}

struct OpaqueIter {
    iter: BoxIter,
    pos: usize,
    done: bool,
}

impl PipelineOp for OpaqueIter {
    fn next(&mut self) -> Option<Example> {
        if self.done {
            return None;
        }
        match self.iter.next() {
            Some(e) => {
                self.pos += 1;
                Some(e)
            }
            None => {
                self.done = true;
                None
            }
        }
    }

    fn state(&mut self) -> Json {
        Json::obj(vec![("op", Json::str("iter")), ("pos", Json::num(self.pos as f64))])
    }

    fn restore(&mut self, s: &Json) -> anyhow::Result<()> {
        check_tag(s, "iter")?;
        let target = field_usize(s, "pos")?;
        anyhow::ensure!(
            self.pos == 0,
            "opaque iterator can only be restored before consumption"
        );
        for i in 0..target {
            anyhow::ensure!(
                self.next().is_some(),
                "stream ended at {i} while replaying to saved position {target}"
            );
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// element-wise ops
// ---------------------------------------------------------------------------

struct MapOp {
    inner: Box<dyn PipelineOp>,
    f: Box<dyn FnMut(Example) -> Example + Send>,
}

impl PipelineOp for MapOp {
    fn next(&mut self) -> Option<Example> {
        self.inner.next().map(|e| (self.f)(e))
    }

    fn state(&mut self) -> Json {
        Json::obj(vec![("op", Json::str("map")), ("inner", self.inner.state())])
    }

    fn restore(&mut self, s: &Json) -> anyhow::Result<()> {
        check_tag(s, "map")?;
        self.inner.restore(field(s, "inner")?)
    }
}

struct FilterOp {
    inner: Box<dyn PipelineOp>,
    f: Box<dyn FnMut(&Example) -> bool + Send>,
}

impl PipelineOp for FilterOp {
    fn next(&mut self) -> Option<Example> {
        loop {
            let e = self.inner.next()?;
            if (self.f)(&e) {
                return Some(e);
            }
        }
    }

    fn state(&mut self) -> Json {
        Json::obj(vec![("op", Json::str("filter")), ("inner", self.inner.state())])
    }

    fn restore(&mut self, s: &Json) -> anyhow::Result<()> {
        check_tag(s, "filter")?;
        self.inner.restore(field(s, "inner")?)
    }
}

struct FlatMapOp {
    inner: Box<dyn PipelineOp>,
    f: Box<dyn FnMut(Example) -> Vec<Example> + Send>,
    /// Expansion of the last consumed upstream example not yet emitted.
    pending: VecDeque<Example>,
}

impl PipelineOp for FlatMapOp {
    fn next(&mut self) -> Option<Example> {
        loop {
            if let Some(e) = self.pending.pop_front() {
                return Some(e);
            }
            let e = self.inner.next()?;
            self.pending.extend((self.f)(e));
        }
    }

    fn state(&mut self) -> Json {
        Json::obj(vec![
            ("op", Json::str("flat_map")),
            ("pending", examples_to_json(self.pending.iter())),
            ("inner", self.inner.state()),
        ])
    }

    fn restore(&mut self, s: &Json) -> anyhow::Result<()> {
        check_tag(s, "flat_map")?;
        self.pending = examples_from_json(field_arr(s, "pending")?)?.into();
        self.inner.restore(field(s, "inner")?)
    }
}

struct EnumerateMapOp {
    inner: Box<dyn PipelineOp>,
    f: Box<dyn FnMut(usize, Example) -> Example + Send>,
    idx: usize,
}

impl PipelineOp for EnumerateMapOp {
    fn next(&mut self) -> Option<Example> {
        let e = self.inner.next()?;
        let i = self.idx;
        self.idx += 1;
        Some((self.f)(i, e))
    }

    fn state(&mut self) -> Json {
        Json::obj(vec![
            ("op", Json::str("enumerate_map")),
            ("idx", Json::num(self.idx as f64)),
            ("inner", self.inner.state()),
        ])
    }

    fn restore(&mut self, s: &Json) -> anyhow::Result<()> {
        check_tag(s, "enumerate_map")?;
        self.idx = field_usize(s, "idx")?;
        self.inner.restore(field(s, "inner")?)
    }
}

struct TakeOp {
    inner: Box<dyn PipelineOp>,
    remaining: usize,
}

impl PipelineOp for TakeOp {
    fn next(&mut self) -> Option<Example> {
        if self.remaining == 0 {
            return None;
        }
        let e = self.inner.next();
        if e.is_some() {
            self.remaining -= 1;
        }
        e
    }

    fn state(&mut self) -> Json {
        Json::obj(vec![
            ("op", Json::str("take")),
            ("remaining", Json::num(self.remaining as f64)),
            ("inner", self.inner.state()),
        ])
    }

    fn restore(&mut self, s: &Json) -> anyhow::Result<()> {
        check_tag(s, "take")?;
        self.remaining = field_usize(s, "remaining")?;
        self.inner.restore(field(s, "inner")?)
    }
}

struct SkipOp {
    inner: Box<dyn PipelineOp>,
    n: usize,
    done: bool,
}

impl PipelineOp for SkipOp {
    fn next(&mut self) -> Option<Example> {
        if !self.done {
            self.done = true;
            for _ in 0..self.n {
                if self.inner.next().is_none() {
                    break;
                }
            }
        }
        self.inner.next()
    }

    fn state(&mut self) -> Json {
        Json::obj(vec![
            ("op", Json::str("skip")),
            ("done", Json::Bool(self.done)),
            ("inner", self.inner.state()),
        ])
    }

    fn restore(&mut self, s: &Json) -> anyhow::Result<()> {
        check_tag(s, "skip")?;
        self.done = field_bool(s, "done")?;
        self.inner.restore(field(s, "inner")?)
    }
}

// ---------------------------------------------------------------------------
// buffering ops
// ---------------------------------------------------------------------------

struct ShuffleOp {
    inner: Box<dyn PipelineOp>,
    buf: Vec<Example>,
    rng: Pcg64,
    window: usize,
    /// Initial window fill completed.
    primed: bool,
    /// Upstream returned None; never poll it again (tf.data end-of-stream
    /// behavior — drains the buffer without a per-element upstream probe).
    exhausted: bool,
}

impl ShuffleOp {
    fn pull(&mut self) {
        match self.inner.next() {
            Some(e) => self.buf.push(e),
            None => self.exhausted = true,
        }
    }
}

impl PipelineOp for ShuffleOp {
    fn next(&mut self) -> Option<Example> {
        if !self.primed {
            while !self.exhausted && self.buf.len() < self.window {
                self.pull();
            }
            self.primed = true;
        } else if !self.exhausted {
            self.pull();
        }
        if self.buf.is_empty() {
            return None;
        }
        let i = self.rng.next_below(self.buf.len() as u64) as usize;
        Some(self.buf.swap_remove(i))
    }

    fn state(&mut self) -> Json {
        Json::obj(vec![
            ("op", Json::str("shuffle")),
            ("rng", rng_to_json(&self.rng)),
            ("primed", Json::Bool(self.primed)),
            ("exhausted", Json::Bool(self.exhausted)),
            ("buf", examples_to_json(self.buf.iter())),
            ("inner", self.inner.state()),
        ])
    }

    fn restore(&mut self, s: &Json) -> anyhow::Result<()> {
        check_tag(s, "shuffle")?;
        self.rng = rng_from_json(field(s, "rng")?)?;
        self.primed = field_bool(s, "primed")?;
        self.exhausted = field_bool(s, "exhausted")?;
        self.buf = examples_from_json(field_arr(s, "buf")?)?;
        self.inner.restore(field(s, "inner")?)
    }
}

struct InterleaveOp {
    parts: Vec<Box<dyn PipelineOp>>,
    next: usize,
}

impl PipelineOp for InterleaveOp {
    fn next(&mut self) -> Option<Example> {
        let n = self.parts.len();
        for _ in 0..n {
            let i = self.next;
            self.next = (self.next + 1) % n;
            if let Some(e) = self.parts[i].next() {
                return Some(e);
            }
        }
        None
    }

    fn state(&mut self) -> Json {
        Json::obj(vec![
            ("op", Json::str("interleave")),
            ("next", Json::num(self.next as f64)),
            (
                "parts",
                Json::Arr(self.parts.iter_mut().map(|p| p.state()).collect()),
            ),
        ])
    }

    fn restore(&mut self, s: &Json) -> anyhow::Result<()> {
        check_tag(s, "interleave")?;
        self.next = field_usize(s, "next")?;
        let parts = field_arr(s, "parts")?;
        anyhow::ensure!(
            parts.len() == self.parts.len(),
            "interleave arity changed: saved {} parts, have {}",
            parts.len(),
            self.parts.len()
        );
        for (p, st) in self.parts.iter_mut().zip(parts) {
            p.restore(st)?;
        }
        Ok(())
    }
}

/// Producer-to-consumer message. Elements and snapshot replies travel
/// through ONE channel, so a `State` reply is ordered after exactly the
/// elements produced before it — the invariant that makes on-request
/// snapshots exact without any per-element state capture.
enum PrefetchMsg {
    Elem(Example),
    /// Reply to a snapshot request: upstream state at the producer's
    /// current position (follows every element sent before it).
    State(Json),
    /// Upstream exhausted; carries the final upstream state.
    End(Json),
}

struct PrefetchOp {
    /// The upstream op; present until the producer thread starts.
    pending: Option<Box<dyn PipelineOp>>,
    buffer: usize,
    rx: Option<PipeReceiver<PrefetchMsg>>,
    /// Snapshot-request line to the producer (unit per request).
    snap_tx: Option<PipeSender<()>>,
    /// Elements drained off the channel while waiting for a snapshot
    /// reply; delivered (in order) before reading the channel again.
    parked: VecDeque<Example>,
    /// Upstream state after the last produced element, once `End` is seen.
    final_state: Option<Json>,
    done: bool,
}

impl PrefetchOp {
    fn start(&mut self) {
        let mut inner = self.pending.take().expect("prefetch already started");
        let (tx, rx) = Pipe::bounded(self.buffer);
        let (snap_tx, snap_rx) = Pipe::<()>::bounded(1);
        std::thread::Builder::new()
            .name("seqio-prefetch".into())
            .spawn(move || {
                loop {
                    // Serve snapshot requests between elements: the reply
                    // rides the element channel, so its position in the
                    // stream pins exactly which elements it follows.
                    while snap_rx.try_recv().is_some() {
                        if !tx.send(PrefetchMsg::State(inner.state())) {
                            return; // consumer hung up
                        }
                    }
                    match inner.next() {
                        Some(e) => {
                            if !tx.send(PrefetchMsg::Elem(e)) {
                                return;
                            }
                        }
                        None => break,
                    }
                }
                let _ = tx.send(PrefetchMsg::End(inner.state()));
            })
            .expect("spawn prefetch thread");
        self.rx = Some(rx);
        self.snap_tx = Some(snap_tx);
    }

    /// Exact upstream state at the delivered-plus-parked position: ask the
    /// producer, park every element that was already in transit, and take
    /// the reply (or the final state if the upstream ended first).
    fn request_snapshot(&mut self) -> Json {
        let requested =
            self.snap_tx.as_ref().map(|t| t.send(())).unwrap_or(false);
        // Even if the request could not be delivered (producer exited
        // after End), the channel must be drained to End so `parked` +
        // `final_state` describe the full stream.
        if requested || !self.done {
            while let Some(msg) = self.rx.as_ref().and_then(|rx| rx.recv()) {
                match msg {
                    PrefetchMsg::Elem(e) => self.parked.push_back(e),
                    PrefetchMsg::State(st) => return st,
                    PrefetchMsg::End(st) => {
                        self.done = true;
                        self.final_state = Some(st.clone());
                        return st;
                    }
                }
            }
            // Channel closed without a reply: the producer died mid-
            // stream (upstream panic). There is no exact state to report.
            self.done = true;
        }
        self.final_state.clone().unwrap_or(Json::Null)
    }
}

impl PipelineOp for PrefetchOp {
    fn next(&mut self) -> Option<Example> {
        if self.pending.is_some() {
            self.start();
        }
        if let Some(e) = self.parked.pop_front() {
            return Some(e);
        }
        if self.done {
            return None;
        }
        loop {
            match self.rx.as_ref().and_then(|rx| rx.recv()) {
                Some(PrefetchMsg::Elem(e)) => return Some(e),
                // A snapshot reply can only appear here if a caller
                // abandoned `state()`'s drain, which never happens —
                // but skipping one is harmless (it is just a position).
                Some(PrefetchMsg::State(_)) => continue,
                Some(PrefetchMsg::End(st)) => {
                    self.done = true;
                    self.final_state = Some(st);
                    return None;
                }
                None => {
                    self.done = true;
                    return None;
                }
            }
        }
    }

    fn state(&mut self) -> Json {
        let inner = match self.pending.as_mut() {
            // Not started: `parked` may still hold restored elements.
            Some(p) => p.state(),
            None => self.request_snapshot(),
        };
        let parked = examples_to_json(self.parked.iter());
        Json::obj(vec![
            ("op", Json::str("prefetch")),
            ("inner", inner),
            // In-transit elements at snapshot time (bounded by `buffer`):
            // serialized here, replayed first after restore.
            ("parked", parked),
        ])
    }

    fn restore(&mut self, s: &Json) -> anyhow::Result<()> {
        check_tag(s, "prefetch")?;
        let p = self
            .pending
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("cannot restore a running prefetch"))?;
        p.restore(field(s, "inner")?)?;
        // Pre-PR5 snapshots carried no parked elements (state was taken
        // per delivered element); treat a missing field as empty.
        self.parked = match s.get("parked") {
            Some(v) => {
                let arr = v.as_arr().ok_or_else(|| {
                    anyhow::anyhow!("prefetch state field 'parked' is not an array")
                })?;
                examples_from_json(arr)?.into()
            }
            None => VecDeque::new(),
        };
        Ok(())
    }
}

/// Order-preserving parallel map. A single coordinator (the op itself)
/// pulls from the upstream, fans work out to `workers` threads, and
/// re-sequences results by input index, so output order never depends on
/// worker scheduling. `state()` is **incremental**: it serializes the
/// already-mapped-but-unemitted results plus the *inputs* still in
/// flight (tracked in `pending_inputs`), without waiting for workers to
/// finish — restore re-dispatches those inputs with their original
/// sequence numbers. `f` must be pure (already required for the
/// order-preservation contract), so re-mapping a replayed input yields
/// the same element the interrupted run would have produced.
struct ParallelMapOp {
    inner: Box<dyn PipelineOp>,
    f: Arc<dyn Fn(Example) -> Example + Send + Sync>,
    workers: usize,
    capacity: u64,
    started: bool,
    work_tx: Option<PipeSender<(u64, Example)>>,
    /// Workers send `Err(panic message)` instead of vanishing, so a panic
    /// in the map fn propagates to the consumer rather than deadlocking.
    result_rx: Option<PipeReceiver<(u64, Result<Example, String>)>>,
    /// Sequence number assigned to the next upstream element.
    next_dispatch: u64,
    /// Sequence number of the next element to emit.
    next_emit: u64,
    reorder: BTreeMap<u64, Example>,
    /// Inputs dispatched to workers whose results have not yet come back,
    /// keyed by sequence number (bounded by `capacity`). These are what a
    /// snapshot serializes instead of quiescing the workers.
    pending_inputs: BTreeMap<u64, Example>,
    /// Restored in-flight inputs awaiting re-dispatch under their
    /// original sequence numbers (drained ahead of fresh upstream pulls).
    replay: VecDeque<(u64, Example)>,
    inner_done: bool,
}

impl ParallelMapOp {
    fn start(&mut self) {
        self.started = true;
        let (work_tx, work_rx) = Pipe::bounded(self.capacity as usize);
        let (result_tx, result_rx) = Pipe::bounded(self.capacity as usize);
        let shared_rx = Arc::new(Mutex::new(work_rx));
        for w in 0..self.workers {
            let rx = shared_rx.clone();
            let tx = result_tx.clone();
            let f = self.f.clone();
            std::thread::Builder::new()
                .name(format!("seqio-pmap-{w}"))
                .spawn(move || loop {
                    let item = rx.lock().unwrap().recv();
                    match item {
                        Some((seq, ex)) => {
                            let out = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(|| f(ex)),
                            )
                            .map_err(|p| panic_message(&p));
                            let died = out.is_err();
                            if !tx.send((seq, out)) || died {
                                break; // consumer hung up / map fn panicked
                            }
                        }
                        None => break, // work channel closed and drained
                    }
                })
                .expect("spawn parallel_map worker");
        }
        self.work_tx = Some(work_tx);
        self.result_rx = Some(result_rx);
    }

    /// Items dispatched to workers whose results have not yet come back.
    fn in_flight(&self) -> u64 {
        self.next_dispatch - self.next_emit - self.reorder.len() as u64
    }

    /// Total lookahead: dispatched but not yet emitted (in workers OR
    /// parked in the reorder buffer). Bounding on this — not `in_flight`
    /// — keeps the reorder buffer from growing without limit when one
    /// straggler element blocks emission while other workers keep
    /// finishing (tf.data's bounded num_parallel_calls lookahead).
    fn outstanding(&self) -> u64 {
        self.next_dispatch - self.next_emit
    }

    /// Keep the workers fed up to `capacity` outstanding items.
    fn dispatch(&mut self) {
        // Restored in-flight inputs bypass the capacity gate: they are
        // already counted by `outstanding()` (they were dispatched before
        // the snapshot), so the gated loop below may never admit them —
        // send them all first, under their original sequence numbers.
        while let Some((seq, ex)) = self.replay.pop_front() {
            let sent = self
                .work_tx
                .as_ref()
                .map(|tx| tx.send((seq, ex)))
                .unwrap_or(false);
            if !sent {
                self.inner_done = true; // workers gone
                return;
            }
        }
        while !self.inner_done && self.outstanding() < self.capacity {
            match self.inner.next() {
                Some(ex) => {
                    self.pending_inputs.insert(self.next_dispatch, ex.clone());
                    let sent = self
                        .work_tx
                        .as_ref()
                        .map(|tx| tx.send((self.next_dispatch, ex)))
                        .unwrap_or(false);
                    if !sent {
                        self.pending_inputs.remove(&self.next_dispatch);
                        self.inner_done = true; // workers gone
                        break;
                    }
                    self.next_dispatch += 1;
                }
                None => {
                    self.inner_done = true;
                    self.work_tx = None; // close so workers exit when drained
                }
            }
        }
    }

    /// Blocking receive of one finished result into the reorder buffer.
    /// Panics if a worker's map fn panicked (propagation, matching
    /// `util::threads::parallel_map`) or if workers died with work still
    /// in flight — both would otherwise hang or silently truncate.
    fn collect_one(&mut self) {
        match self.result_rx.as_ref().and_then(|rx| rx.recv()) {
            Some((seq, Ok(e))) => {
                self.pending_inputs.remove(&seq);
                self.reorder.insert(seq, e);
            }
            Some((_, Err(msg))) => {
                panic!("parallel_map worker panicked: {msg}");
            }
            None => panic!(
                "parallel_map workers exited with {} items in flight",
                self.in_flight()
            ),
        }
    }
}

/// Best-effort text of a caught panic payload.
fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

impl PipelineOp for ParallelMapOp {
    fn next(&mut self) -> Option<Example> {
        if !self.started {
            self.start();
        }
        loop {
            if let Some(e) = self.reorder.remove(&self.next_emit) {
                self.next_emit += 1;
                return Some(e);
            }
            self.dispatch();
            if self.in_flight() == 0 {
                return None;
            }
            self.collect_one();
        }
    }

    fn state(&mut self) -> Json {
        // Incremental snapshot: no quiescing. Results already collected
        // are serialized with their sequence numbers (the reorder buffer
        // may have holes behind a straggler), and inputs still in flight
        // are serialized as `pending` — restore re-dispatches them, so
        // the workers never have to be drained to take state. Replayed
        // inputs not yet re-sent count as pending too (`self.replay` is a
        // subset of `pending_inputs` until `dispatch` drains it).
        Json::obj(vec![
            ("op", Json::str("parallel_map")),
            ("emitted", Json::num(self.next_emit as f64)),
            ("done", seq_examples_to_json(self.reorder.iter())),
            ("pending", seq_examples_to_json(self.pending_inputs.iter())),
            ("inner", self.inner.state()),
        ])
    }

    fn restore(&mut self, s: &Json) -> anyhow::Result<()> {
        check_tag(s, "parallel_map")?;
        anyhow::ensure!(!self.started, "cannot restore a running parallel_map");
        let emitted = field_usize(s, "emitted")? as u64;
        self.next_emit = emitted;
        self.reorder.clear();
        self.pending_inputs.clear();
        self.replay.clear();
        if s.get("pending").is_some() {
            for (seq, e) in seq_examples_from_json(field_arr(s, "done")?)? {
                self.reorder.insert(seq, e);
            }
            for (seq, e) in seq_examples_from_json(field_arr(s, "pending")?)? {
                self.pending_inputs.insert(seq, e.clone());
                self.replay.push_back((seq, e));
            }
        } else {
            // Legacy quiescing snapshot: a contiguous run of mapped
            // outputs starting at `emitted`, nothing in flight.
            let buffered = examples_from_json(field_arr(s, "buffered")?)?;
            for (i, e) in buffered.into_iter().enumerate() {
                self.reorder.insert(emitted + i as u64, e);
            }
        }
        // Every seq in [next_emit, next_dispatch) is in exactly one of
        // reorder / pending_inputs, so the union's size positions the
        // dispatch cursor.
        self.next_dispatch =
            emitted + (self.reorder.len() + self.pending_inputs.len()) as u64;
        self.inner.restore(field(s, "inner")?)
    }
}

/// `[seq, example]` pairs for the parallel_map snapshot (seqs as hex
/// strings, like every u64 in pipeline state).
fn seq_examples_to_json<'a>(
    it: impl Iterator<Item = (&'a u64, &'a Example)>,
) -> Json {
    Json::Arr(
        it.map(|(seq, e)| Json::Arr(vec![u64_to_json(*seq), example_to_json(e)]))
            .collect(),
    )
}

fn seq_examples_from_json(v: &[Json]) -> anyhow::Result<Vec<(u64, Example)>> {
    v.iter()
        .map(|pair| {
            let arr = pair.as_arr().filter(|a| a.len() == 2).ok_or_else(|| {
                anyhow::anyhow!(
                    "parallel_map state entry is not a [seq, example] pair"
                )
            })?;
            Ok((u64_from_json(&arr[0])?, example_from_json(&arr[1])?))
        })
        .collect()
}

// ---------------------------------------------------------------------------
// factories and epoch repetition
// ---------------------------------------------------------------------------

/// A re-instantiable dataset (source of truth for `repeat`): seqio Tasks
/// hand out factories so epochs can restart the stream deterministically.
pub struct DatasetFactory {
    make: Box<dyn Fn() -> Dataset + Send + Sync>,
}

impl DatasetFactory {
    pub fn new(make: impl Fn() -> Dataset + Send + Sync + 'static) -> Self {
        Self { make: Box::new(make) }
    }

    pub fn instantiate(&self) -> Dataset {
        (self.make)()
    }

    /// Infinite repetition across epochs. Epoch k's stream is the k-th
    /// fresh instantiation, so state is (epoch, position-within-epoch).
    pub fn repeat(self: Arc<Self>) -> Dataset {
        let cur = self.instantiate().op;
        Dataset::from_op(RepeatOp { factory: self, cur, epoch: 0 })
    }
}

struct RepeatOp {
    factory: Arc<DatasetFactory>,
    cur: Box<dyn PipelineOp>,
    epoch: u64,
}

impl PipelineOp for RepeatOp {
    fn next(&mut self) -> Option<Example> {
        if let Some(e) = self.cur.next() {
            return Some(e);
        }
        // Epoch boundary: restart once; an empty dataset ends the stream
        // instead of looping forever.
        let mut fresh = self.factory.instantiate().op;
        match fresh.next() {
            Some(e) => {
                self.cur = fresh;
                self.epoch += 1;
                Some(e)
            }
            None => None,
        }
    }

    fn state(&mut self) -> Json {
        Json::obj(vec![
            ("op", Json::str("repeat")),
            ("epoch", Json::num(self.epoch as f64)),
            ("cur", self.cur.state()),
        ])
    }

    fn restore(&mut self, s: &Json) -> anyhow::Result<()> {
        check_tag(s, "repeat")?;
        self.epoch = field_usize(s, "epoch")? as u64;
        // Every epoch's stream is an identical fresh instantiation, so the
        // current (epoch-0) instance restores to any epoch's position.
        self.cur.restore(field(s, "cur")?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::seqio::{ints_example, Feature};

    fn nums(n: usize) -> Vec<Example> {
        (0..n).map(|i| ints_example(&[("x", vec![i as i32])])).collect()
    }

    fn xs(d: Dataset) -> Vec<i32> {
        d.collect_vec()
            .iter()
            .map(|e| e["x"].as_ints().unwrap()[0])
            .collect()
    }

    #[test]
    fn map_filter_take_skip() {
        let d = Dataset::from_vec(nums(10))
            .map(|mut e| {
                if let Feature::Ints(v) = e.get_mut("x").unwrap() {
                    v[0] *= 2;
                }
                e
            })
            .filter(|e| e["x"].as_ints().unwrap()[0] % 4 == 0)
            .skip(1)
            .take(3);
        assert_eq!(xs(d), vec![4, 8, 12]);
    }

    #[test]
    fn shuffle_is_seeded_permutation() {
        let a = xs(Dataset::from_vec(nums(100)).shuffle_window(32, 7));
        let b = xs(Dataset::from_vec(nums(100)).shuffle_window(32, 7));
        let c = xs(Dataset::from_vec(nums(100)).shuffle_window(32, 8));
        assert_eq!(a, b);
        assert_ne!(a, c);
        let mut sorted = a.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(a, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn shuffle_stops_polling_exhausted_upstream() {
        // tf.data end-of-stream semantics: once the upstream returns None,
        // draining the buffer must not probe the upstream again.
        let calls = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let c2 = calls.clone();
        let n = 20usize;
        let counted = (0..=n).filter_map(move |i| {
            c2.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            if i < n {
                Some(ints_example(&[("x", vec![i as i32])]))
            } else {
                None
            }
        });
        let out = xs(Dataset::new(counted).shuffle_window(8, 3));
        assert_eq!(out.len(), n);
        // n Some-calls + exactly one None probe.
        assert_eq!(calls.load(std::sync::atomic::Ordering::Relaxed), n + 1);
    }

    #[test]
    fn interleave_round_robin() {
        let d1 = Dataset::from_vec(nums(3));
        let d2 = Dataset::from_vec(
            (10..12).map(|i| ints_example(&[("x", vec![i])])).collect(),
        );
        let out = xs(Dataset::interleave(vec![d1, d2]));
        assert_eq!(out, vec![0, 10, 1, 11, 2]);
    }

    #[test]
    fn prefetch_preserves_order() {
        let out = xs(Dataset::from_vec(nums(50)).prefetch(4));
        assert_eq!(out, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn factory_repeat() {
        let f = Arc::new(DatasetFactory::new(|| Dataset::from_vec(nums(3))));
        let out = xs(f.repeat().take(8));
        assert_eq!(out, vec![0, 1, 2, 0, 1, 2, 0, 1]);
    }

    #[test]
    fn enumerate_map_sees_positions() {
        let d = Dataset::from_vec(nums(5)).enumerate_map(|i, mut e| {
            if let Feature::Ints(v) = e.get_mut("x").unwrap() {
                v[0] += 100 * i as i32;
            }
            e
        });
        assert_eq!(xs(d), vec![0, 101, 202, 303, 404]);
    }

    // -- stateful pipeline tests -------------------------------------------

    /// The canonical test pipeline: every op class in one chain.
    fn chain(n: usize) -> Dataset {
        Dataset::from_vec(nums(n))
            .map(|mut e| {
                if let Feature::Ints(v) = e.get_mut("x").unwrap() {
                    v[0] += 1;
                }
                e
            })
            .filter(|e| e["x"].as_ints().unwrap()[0] % 3 != 0)
            .flat_map(|e| vec![e.clone(), e])
            .enumerate_map(|i, mut e| {
                if let Feature::Ints(v) = e.get_mut("x").unwrap() {
                    v[0] += 1000 * (i as i32 % 2);
                }
                e
            })
            .shuffle_window(7, 42)
    }

    #[test]
    fn state_roundtrip_resumes_exact_stream() {
        for cut in [0usize, 1, 5, 13, 29] {
            let mut full = chain(40);
            let all: Vec<Example> = (&mut full).collect();

            let mut first = chain(40);
            let head: Vec<Example> = (&mut first).take(cut).collect();
            let snap = first.state();

            let mut resumed = chain(40);
            resumed.restore(&snap).unwrap();
            let tail: Vec<Example> = resumed.collect();

            let mut joined = head;
            joined.extend(tail);
            assert_eq!(joined, all, "cut={cut}");
        }
    }

    #[test]
    fn state_roundtrips_through_json_text() {
        let mut first = chain(30);
        let head: Vec<Example> = (&mut first).take(11).collect();
        let text = first.state().to_json_string();
        let snap = PipelineState::parse(&text).unwrap();

        let mut resumed = chain(30);
        resumed.restore(&snap).unwrap();
        let tail: Vec<Example> = resumed.collect();

        let mut full = chain(30);
        let all: Vec<Example> = (&mut full).collect();
        let mut joined = head;
        joined.extend(tail);
        assert_eq!(joined, all);
    }

    #[test]
    fn restore_rejects_mismatched_pipeline() {
        let mut a = Dataset::from_vec(nums(5)).take(3);
        let snap = a.state();
        let mut b = Dataset::from_vec(nums(5)).skip(1);
        assert!(b.restore(&snap).is_err());
    }

    #[test]
    fn repeat_state_resumes_across_epochs() {
        let f = Arc::new(DatasetFactory::new(|| Dataset::from_vec(nums(4))));
        let mut first = f.clone().repeat();
        let head: Vec<i32> = (&mut first)
            .take(10)
            .map(|e| e["x"].as_ints().unwrap()[0])
            .collect();
        assert_eq!(head, vec![0, 1, 2, 3, 0, 1, 2, 3, 0, 1]);
        let snap = first.state();

        let mut resumed = f.repeat();
        resumed.restore(&snap).unwrap();
        // NB: inherent `take`/`map` shadow the Iterator adaptors, so go
        // through `&mut` to keep plain Iterator semantics.
        let tail: Vec<i32> = (&mut resumed)
            .take(6)
            .map(|e| e["x"].as_ints().unwrap()[0])
            .collect();
        assert_eq!(tail, vec![2, 3, 0, 1, 2, 3]);
    }

    #[test]
    fn parallel_map_matches_serial_map_order() {
        let f = |mut e: Example| {
            if let Feature::Ints(v) = e.get_mut("x").unwrap() {
                v[0] = v[0] * 7 + 1;
            }
            e
        };
        let serial = xs(Dataset::from_vec(nums(200)).map(f));
        for workers in [1usize, 2, 4] {
            let par = xs(Dataset::from_vec(nums(200)).parallel_map(f, workers));
            assert_eq!(par, serial, "workers={workers}");
        }
    }

    #[test]
    fn parallel_map_state_roundtrip() {
        let f = |mut e: Example| {
            if let Feature::Ints(v) = e.get_mut("x").unwrap() {
                v[0] += 500;
            }
            e
        };
        let build = || Dataset::from_vec(nums(60)).parallel_map(f, 4);
        let all = xs(build());

        let mut first = build();
        let head: Vec<i32> = (&mut first)
            .take(23)
            .map(|e| e["x"].as_ints().unwrap()[0])
            .collect();
        let snap = first.state();

        let mut resumed = build();
        resumed.restore(&snap).unwrap();
        let tail: Vec<i32> =
            (&mut resumed).map(|e| e["x"].as_ints().unwrap()[0]).collect();

        let mut joined = head;
        joined.extend(tail);
        assert_eq!(joined, all);
    }

    #[test]
    fn parallel_map_snapshot_is_exact_at_every_cut_point() {
        // Incremental snapshot contract: wherever state is taken —
        // including with work still in flight on the workers — restore +
        // drain yields exactly the not-yet-emitted suffix, and the
        // snapshotted stream itself is undisturbed.
        let f = |mut e: Example| {
            if let Feature::Ints(v) = e.get_mut("x").unwrap() {
                v[0] = v[0] * 3 + 1;
            }
            e
        };
        let n = 30usize;
        let build = || Dataset::from_vec(nums(n)).parallel_map(f, 3);
        let all = xs(build());
        for cut in 0..=n {
            let mut first = build();
            let head: Vec<i32> = (&mut first)
                .take(cut)
                .map(|e| e["x"].as_ints().unwrap()[0])
                .collect();
            let snap = first.state();
            let mut resumed = build();
            resumed.restore(&snap).unwrap();
            let tail: Vec<i32> =
                (&mut resumed).map(|e| e["x"].as_ints().unwrap()[0]).collect();
            let mut joined = head;
            joined.extend(tail);
            assert_eq!(joined, all, "cut={cut}");
            // the original stream is not disturbed by the snapshot
            let rest: Vec<i32> =
                (&mut first).map(|e| e["x"].as_ints().unwrap()[0]).collect();
            assert_eq!(rest, &all[cut..], "cut={cut}");
        }
    }

    #[test]
    fn parallel_map_repeated_snapshots_and_pending_carryover() {
        let f = |mut e: Example| {
            if let Feature::Ints(v) = e.get_mut("x").unwrap() {
                v[0] += 500;
            }
            e
        };
        let build = || Dataset::from_vec(nums(40)).parallel_map(f, 4);
        let expect = xs(build());
        let mut d = build();
        let _ = (&mut d).take(13).count();
        // two snapshots with no consumption in between must agree
        let s1 = d.state();
        let s2 = d.state();
        for s in [&s1, &s2] {
            let mut r = build();
            r.restore(s).unwrap();
            let tail: Vec<i32> =
                (&mut r).map(|e| e["x"].as_ints().unwrap()[0]).collect();
            assert_eq!(tail, &expect[13..]);
        }
        // snapshot-of-a-restore (replayed inputs still pending, nothing
        // re-dispatched yet) must carry the in-flight inputs forward
        let mut r = build();
        r.restore(&s1).unwrap();
        let s3 = r.state();
        let mut r2 = build();
        r2.restore(&s3).unwrap();
        let tail: Vec<i32> =
            (&mut r2).map(|e| e["x"].as_ints().unwrap()[0]).collect();
        assert_eq!(tail, &expect[13..]);
    }

    #[test]
    fn parallel_map_restores_legacy_quiesced_state() {
        // Pre-PR9 snapshots quiesced the workers and carried a contiguous
        // 'buffered' run of mapped outputs (no 'pending' field); they
        // must still restore.
        let f = |mut e: Example| {
            if let Feature::Ints(v) = e.get_mut("x").unwrap() {
                v[0] += 500;
            }
            e
        };
        let n = 10usize;
        let build = || Dataset::from_vec(nums(n)).parallel_map(f, 2);
        let expect = xs(build());
        // emitted 4, mapped outputs for seqs 4..6 buffered, upstream at 6
        let buffered: Vec<Example> =
            nums(n).into_iter().skip(4).take(2).map(f).collect();
        let legacy = PipelineState(Json::obj(vec![
            ("op", Json::str("parallel_map")),
            ("emitted", Json::num(4.0)),
            ("buffered", examples_to_json(buffered.iter())),
            (
                "inner",
                Json::obj(vec![("op", Json::str("vec")), ("pos", Json::num(6.0))]),
            ),
        ]));
        let mut r = build();
        r.restore(&legacy).unwrap();
        let tail: Vec<i32> =
            (&mut r).map(|e| e["x"].as_ints().unwrap()[0]).collect();
        assert_eq!(tail, &expect[4..]);
    }

    #[test]
    fn parallel_map_propagates_worker_panic() {
        let r = std::panic::catch_unwind(|| {
            Dataset::from_vec(nums(10))
                .parallel_map(
                    |e| {
                        if e["x"].as_ints().unwrap()[0] == 5 {
                            panic!("boom");
                        }
                        e
                    },
                    2,
                )
                .collect_vec()
        });
        assert!(r.is_err(), "worker panic must propagate, not hang/truncate");
    }

    #[test]
    fn prefetch_state_reflects_delivered_elements() {
        let build = || Dataset::from_vec(nums(30)).prefetch(4);
        let mut first = build();
        let head: Vec<i32> = (&mut first)
            .take(9)
            .map(|e| e["x"].as_ints().unwrap()[0])
            .collect();
        assert_eq!(head, (0..9).collect::<Vec<_>>());
        let snap = first.state();

        let mut resumed = build();
        resumed.restore(&snap).unwrap();
        let tail: Vec<i32> =
            (&mut resumed).map(|e| e["x"].as_ints().unwrap()[0]).collect();
        assert_eq!(tail, (9..30).collect::<Vec<_>>());
    }

    #[test]
    fn prefetch_snapshot_is_exact_at_every_boundary() {
        // The on-request snapshot contract: wherever state is taken (the
        // infeed takes it at batch boundaries), restore + drain yields
        // exactly the not-yet-delivered suffix — including elements that
        // were in transit in the prefetch buffer (serialized as 'parked').
        let build = || Dataset::from_vec(nums(24)).prefetch(3);
        for cut in [0usize, 1, 3, 7, 23, 24] {
            let mut first = build();
            let head: Vec<i32> = (&mut first)
                .take(cut)
                .map(|e| e["x"].as_ints().unwrap()[0])
                .collect();
            assert_eq!(head, (0..cut as i32).collect::<Vec<_>>());
            let snap = first.state();
            let mut resumed = build();
            resumed.restore(&snap).unwrap();
            let tail: Vec<i32> =
                (&mut resumed).map(|e| e["x"].as_ints().unwrap()[0]).collect();
            assert_eq!(tail, (cut as i32..24).collect::<Vec<_>>(), "cut={cut}");
            // the original stream is NOT disturbed by the snapshot
            let rest: Vec<i32> =
                (&mut first).map(|e| e["x"].as_ints().unwrap()[0]).collect();
            assert_eq!(rest, (cut as i32..24).collect::<Vec<_>>(), "cut={cut}");
        }
    }

    #[test]
    fn prefetch_repeated_snapshots_and_parked_carryover() {
        // Two snapshots with no consumption in between must agree, and a
        // snapshot taken right after restore (parked elements pending)
        // must carry them.
        let build = || Dataset::from_vec(nums(20)).prefetch(4);
        let mut d = build();
        let _ = (&mut d).take(6).count();
        let s1 = d.state();
        let s2 = d.state();
        // both snapshots restore to the same continuation
        for s in [&s1, &s2] {
            let mut r = build();
            r.restore(s).unwrap();
            let tail: Vec<i32> =
                (&mut r).map(|e| e["x"].as_ints().unwrap()[0]).collect();
            assert_eq!(tail, (6..20).collect::<Vec<_>>());
        }
        // snapshot-of-a-restore (before consuming) preserves parked rows
        let mut r = build();
        r.restore(&s1).unwrap();
        let s3 = r.state();
        let mut r2 = build();
        r2.restore(&s3).unwrap();
        let tail: Vec<i32> =
            (&mut r2).map(|e| e["x"].as_ints().unwrap()[0]).collect();
        assert_eq!(tail, (6..20).collect::<Vec<_>>());
    }

    #[test]
    fn prefetch_restores_legacy_state_without_parked_field() {
        // Pre-PR5 snapshots paired state with every delivered element and
        // carried no 'parked' array — they must still restore.
        let build = || Dataset::from_vec(nums(10)).prefetch(2);
        let mut d = build();
        let _ = (&mut d).take(4).count();
        let snap = d.state();
        let legacy = PipelineState(match snap.0 {
            Json::Obj(fields) => Json::Obj(
                fields.into_iter().filter(|(k, _)| k.as_str() != "parked").collect(),
            ),
            _ => panic!("prefetch state must be an object"),
        });
        let mut r = build();
        r.restore(&legacy).unwrap();
        let tail: Vec<i32> =
            (&mut r).map(|e| e["x"].as_ints().unwrap()[0]).collect();
        assert_eq!(tail, (4..10).collect::<Vec<_>>());
    }

    #[test]
    fn prefetch_downstream_of_parallel_map_snapshots_cheaply() {
        // The documented anti-pattern is gone: prefetch may sit right
        // after parallel_map; steady-state production does no state
        // serialization and snapshots stay exact.
        let build = || {
            Dataset::from_vec(nums(40))
                .parallel_map(
                    |mut e| {
                        let x = e["x"].as_ints().unwrap()[0];
                        e.insert("y".into(), Feature::Ints(vec![x * 2]));
                        e
                    },
                    2,
                )
                .prefetch(4)
        };
        let mut d = build();
        let _ = (&mut d).take(11).count();
        let snap = d.state();
        let mut r = build();
        r.restore(&snap).unwrap();
        let tail: Vec<i32> =
            (&mut r).map(|e| e["y"].as_ints().unwrap()[0]).collect();
        assert_eq!(tail, (11..40).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn interleave_state_roundtrip() {
        let build = || {
            Dataset::interleave(vec![
                Dataset::from_vec(nums(5)),
                Dataset::from_vec(
                    (100..103).map(|i| ints_example(&[("x", vec![i])])).collect(),
                ),
            ])
        };
        let all = xs(build());
        let mut first = build();
        let head: Vec<i32> = (&mut first)
            .take(4)
            .map(|e| e["x"].as_ints().unwrap()[0])
            .collect();
        let snap = first.state();
        let mut resumed = build();
        resumed.restore(&snap).unwrap();
        let tail: Vec<i32> =
            (&mut resumed).map(|e| e["x"].as_ints().unwrap()[0]).collect();
        let mut joined = head;
        joined.extend(tail);
        assert_eq!(joined, all);
    }
}
