"""AOT exporter: lower the L2/L1 computations to HLO text + manifest.json.

This is the only place Python touches the artifact directory; the Rust L3
binary is self-contained afterwards. Interchange is HLO *text* (NOT
``.serialize()``): jax >= 0.5 emits HloModuleProtos with 64-bit instruction
ids which xla_extension 0.5.1 (the version the published ``xla`` crate
binds) rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/load_hlo and its README.

Exports, per model config in ``model.CONFIGS``:
  <model>/train_step.hlo.txt   (params.., batch..) -> (loss_sum, weight_sum,
                                correct_sum, grads..)
  <model>/eval_step.hlo.txt    (params.., batch..) -> (loss_sum, weight_sum,
                                correct_sum)
  <model>/decode_logits.hlo.txt (params.., tokens..) -> (logits,)
  <model>/block_m<n>/<segment>.hlo.txt — model-parallel train-step segments
                                per model-axis degree n (block_exec contract)
plus:
  bench/{scan,unroll}_L{2,4,8}.hlo.txt   — Scalable T5 compile-time claim (E12)
  partdemo/ffn_{full,shard2,shard4}.hlo.txt — Megatron MLP sharding demo (E3)
  golden.json                   — loss/grad goldens for pattern-init params,
                                  cross-checked by Rust integration tests
  manifest.json                 — the artifact contract consumed by Rust
"""

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(path: str, text: str):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)


# ---------------------------------------------------------------------------
# Deterministic golden batch (formula mirrored by rust/src/model/golden.rs)
# ---------------------------------------------------------------------------


def golden_batch(cfg: M.ModelConfig):
    b, l, v = cfg.batch, cfg.seq_len, cfg.vocab
    tgt = np.fromfunction(
        lambda i, j: (i * 7919 + j * 104729 + 13) % (v - 2) + 2, (b, l), dtype=np.int64
    ).astype(np.int32)
    dec_in = np.zeros_like(tgt)
    dec_in[:, 1:] = tgt[:, :-1]
    weights = np.ones((b, l), np.float32)
    weights[0, -4:] = 0.0
    batch = {
        "decoder_input_tokens": dec_in,
        "decoder_target_tokens": tgt,
        "decoder_loss_weights": weights,
    }
    if cfg.arch == "encdec":
        batch["encoder_input_tokens"] = np.fromfunction(
            lambda i, j: (i * 6101 + j * 3571 + 29) % (v - 2) + 2, (b, l), dtype=np.int64
        ).astype(np.int32)
    return batch


def export_model(cfg: M.ModelConfig, out_dir: str, entry: dict):
    specs = M.param_specs(cfg)
    param_shapes = [jax.ShapeDtypeStruct(s[1], jnp.float32) for s in specs]
    bshapes = M.batch_shapes(cfg)
    bfeat = M.batch_feature_names(cfg)

    train_fn, _ = M.train_step_fn(cfg)
    eval_fn, _ = M.eval_step_fn(cfg)
    dec_fn, _ = M.decode_logits_fn(cfg)

    t0 = time.time()
    train_args = param_shapes + [bshapes[f] for f in bfeat]
    _write(
        f"{out_dir}/{cfg.name}/train_step.hlo.txt",
        to_hlo_text(jax.jit(train_fn).lower(*train_args)),
    )
    _write(
        f"{out_dir}/{cfg.name}/eval_step.hlo.txt",
        to_hlo_text(jax.jit(eval_fn).lower(*train_args)),
    )
    tok_shapes = [bshapes[f] for f in bfeat if f.endswith("input_tokens")]
    _write(
        f"{out_dir}/{cfg.name}/decode_logits.hlo.txt",
        to_hlo_text(jax.jit(dec_fn).lower(*(param_shapes + tok_shapes))),
    )
    # KV-cached incremental decoding (decoder-only): prefill scores the
    # prompt buffer once and materializes the cache; decode_step extends it
    # by one position per row — the O(L) serving hot path.
    kv = cfg.arch == "decoder"
    if kv:
        pf_fn, _ = M.prefill_fn(cfg)
        _write(
            f"{out_dir}/{cfg.name}/prefill.hlo.txt",
            to_hlo_text(jax.jit(pf_fn).lower(*(param_shapes + tok_shapes))),
        )
        ds_fn, _ = M.decode_step_fn(cfg)
        step_args = (
            param_shapes
            + M.kv_cache_shapes(cfg)
            + [
                jax.ShapeDtypeStruct((cfg.batch, 1), jnp.int32),
                jax.ShapeDtypeStruct((cfg.batch,), jnp.int32),
            ]
        )
        _write(
            f"{out_dir}/{cfg.name}/decode_step.hlo.txt",
            to_hlo_text(jax.jit(ds_fn).lower(*step_args)),
        )
    print(f"  {cfg.name}: exported in {time.time() - t0:.1f}s")
    cache_names = [
        f"cache:decoder.layers_{i}.{t}"
        for i in range(cfg.num_layers)
        for t in ("k", "v")
    ]

    entry[cfg.name] = {
        "arch": cfg.arch,
        "config": {
            k: v
            for k, v in dataclasses.asdict(cfg).items()
            if isinstance(v, (int, float, str, bool))
        },
        "params": [
            {
                "name": n,
                "shape": list(shape),
                "dtype": "f32",
                "logical_axes": list(axes),
                "init": init,
            }
            for (n, shape, axes, init) in specs
        ],
        "batch_features": [
            {
                "name": f,
                "shape": list(bshapes[f].shape),
                "dtype": "i32" if bshapes[f].dtype == jnp.int32 else "f32",
            }
            for f in bfeat
        ],
        "entrypoints": {
            "train_step": {
                "hlo": f"{cfg.name}/train_step.hlo.txt",
                "outputs": ["loss_sum", "weight_sum", "correct_sum"]
                + [f"grad:{s[0]}" for s in specs],
            },
            "eval_step": {
                "hlo": f"{cfg.name}/eval_step.hlo.txt",
                "outputs": ["loss_sum", "weight_sum", "correct_sum"],
            },
            "decode_logits": {
                "hlo": f"{cfg.name}/decode_logits.hlo.txt",
                "inputs": [f for f in bfeat if f.endswith("input_tokens")],
                "outputs": ["logits"],
            },
        },
    }
    if kv:
        entry[cfg.name]["entrypoints"]["prefill"] = {
            "hlo": f"{cfg.name}/prefill.hlo.txt",
            "inputs": ["decoder_input_tokens"],
            "outputs": ["logits"] + cache_names,
        }
        entry[cfg.name]["entrypoints"]["decode_step"] = {
            "hlo": f"{cfg.name}/decode_step.hlo.txt",
            "inputs": cache_names + ["token", "pos"],
            "outputs": ["logits"] + cache_names,
        }
        # The cache contract consumed by the Rust engine: per-layer k/v
        # tensors, [B, H, L, head_dim] f32, batch-major so one request's
        # cache is a contiguous row slice (slot recycling on refill).
        entry[cfg.name]["kv_cache"] = {
            "layout": ["batch", "heads", "seq", "head_dim"],
            "shape": [cfg.batch, cfg.num_heads, cfg.seq_len, cfg.head_dim],
            "dtype": "f32",
            "num_layers": cfg.num_layers,
            "per_layer": ["k", "v"],
        }


# Model-parallel block execution exports (§2.2). Degrees per model: every
# listed degree whose sharded dims divide (see model.supports_block_degree).
BLOCK_DEGREES = {
    "t5-nano-dec": (2, 4),
    "t5-micro-dec": (2, 4),
}


def export_block(cfg: M.ModelConfig, out_dir: str, entry: dict, degrees):
    """Export the block train-step segments + `block_exec` manifest contract.

    Per degree n: 12 segment HLOs under <model>/block_m<n>/ (layer weights
    are segment INPUTS, so depth does not multiply the HLO count), the
    per-parameter block shapes, the ordered model-axis collective schedule
    (op/dtype/elems/bytes), and the fused replicated-grad name list. The
    Rust trainer replays exactly this schedule between segment executions.
    """
    fns = M.block_segment_fns(cfg)
    block = {}
    for n in degrees:
        if not M.supports_block_degree(cfg, n):
            print(f"  {cfg.name}: degree {n} not divisible, skipped")
            continue
        t0 = time.time()
        shapes = M.block_segment_shapes(cfg, n)
        segments = {}
        for seg in M.BLOCK_SEGMENT_NAMES:
            path = f"{cfg.name}/block_m{n}/{seg}.hlo.txt"
            _write(
                f"{out_dir}/{path}",
                to_hlo_text(jax.jit(fns[seg]).lower(*shapes[seg])),
            )
            segments[seg] = {"hlo": path}
        block[str(n)] = {
            "params": M.model_block_specs(cfg, n),
            "segments": segments,
            "collectives": [
                {
                    "point": point,
                    "op": op,
                    "dtype": "f32",
                    "elems": elems,
                    "bytes": elems * 4,
                    "axis": "model",
                }
                for (point, op, elems) in M.block_collective_schedule(cfg, n)
            ],
            "replicated_grads": M.block_replicated_params(cfg, n),
        }
        print(f"  {cfg.name}: block degree {n} exported in {time.time() - t0:.1f}s")
    if block:
        entry[cfg.name]["block_exec"] = {"degrees": block}


def export_block_golden(cfg: M.ModelConfig, degrees, goldens: dict):
    """Export gate: the simulated block schedule (the exact segment +
    collective sequence Rust replays) must match the monolithic train_step
    on pattern params + golden batch. Sums are reordered across the model
    axis (row-parallel K-splits reduce via AR instead of inside one matmul),
    so agreement is close-but-not-bitwise; the measured gaps are recorded
    for the Rust tests' tolerances. correct_sum can legitimately differ at
    exact logit ties and is compared at weight granularity."""
    params = M.pattern_params(cfg)
    batch = golden_batch(cfg)
    train_fn, names = M.train_step_fn(cfg)
    args = [params[n] for n in names] + [
        jnp.asarray(batch[f]) for f in M.batch_feature_names(cfg)
    ]
    outs = jax.jit(train_fn)(*args)
    ref_loss = float(outs[0])
    ref_grads = dict(zip(names, outs[3:]))
    entry = goldens.setdefault(cfg.name, {}).setdefault("block_exec", {})
    for n in degrees:
        if not M.supports_block_degree(cfg, n):
            continue
        ls, ws, cs, grads = M.block_reference_step(cfg, n, params, batch)
        loss_gap = abs(float(ls) - ref_loss) / max(1.0, abs(ref_loss))
        assert loss_gap < 1e-5, f"{cfg.name} m={n}: block loss diverged: {loss_gap}"
        assert float(ws) == float(outs[1])
        assert abs(float(cs) - float(outs[2])) < 1.5, "argmax claim broken"
        max_grad_gap = 0.0
        for name in names:
            a = np.asarray(ref_grads[name], np.float32)
            b = np.asarray(grads[name], np.float32)
            denom = max(1e-6, float(np.abs(a).max()))
            gap = float(np.abs(a - b).max()) / denom
            assert gap < 1e-3, f"{cfg.name} m={n}: grad {name} diverged: {gap}"
            max_grad_gap = max(max_grad_gap, gap)
        entry[str(n)] = {
            "rel_loss_gap": loss_gap,
            "max_rel_grad_gap": max_grad_gap,
        }
        print(
            f"  block golden {cfg.name} m={n}: rel loss gap {loss_gap:.2e},"
            f" max rel grad gap {max_grad_gap:.2e}"
        )


def export_golden(cfg: M.ModelConfig, goldens: dict):
    """Loss + grad-norm goldens for pattern-init params on the golden batch."""
    params = M.pattern_params(cfg)
    batch = golden_batch(cfg)
    train_fn, names = M.train_step_fn(cfg)
    args = [params[n] for n in names] + [
        jnp.asarray(batch[f]) for f in M.batch_feature_names(cfg)
    ]
    outs = jax.jit(train_fn)(*args)
    loss_sum, weight_sum, correct_sum = (float(x) for x in outs[:3])
    grad_norms = {
        n: float(jnp.linalg.norm(g.astype(jnp.float32)))
        for n, g in zip(names, outs[3:])
    }
    goldens[cfg.name] = {
        "init": "pattern:seed=0:scale=0.05",
        "loss_sum": loss_sum,
        "weight_sum": weight_sum,
        "correct_sum": correct_sum,
        "grad_norms": grad_norms,
    }
    print(
        f"  golden {cfg.name}: loss_sum={loss_sum:.4f} weight_sum={weight_sum}"
        f" correct_sum={correct_sum}"
    )


def export_kv_golden(cfg: M.ModelConfig, goldens: dict):
    """KV-cache consistency golden: prefill + N x decode_step logits must
    match full `logits_fn` rescoring position-by-position (the O(L) path is
    a re-lowering, not a re-definition, of the model). Fails the export on
    divergence and records the max absolute logits gap plus the greedy
    continuation of a deterministic prompt (pattern-init params).

    The prompt fills half the buffer so the single-query relpos-bias path
    is exercised at long distances (the log-bucket branch that L=128
    serving leans on), not just the near-diagonal L=32 regime.
    """
    assert cfg.arch == "decoder"
    params = M.pattern_params(cfg)
    b, l, v = cfg.batch, cfg.seq_len, cfg.vocab
    prompt_len = max(4, min(l // 2, l - 8))
    steps = min(6, l - 1 - prompt_len)
    # Shifted-right buffer: BOS(0) at position 0, prompt at 1..=prompt_len.
    dec = np.zeros((b, l), np.int32)
    for i in range(b):
        for j in range(prompt_len):
            dec[i, 1 + j] = (i * 131 + j * 17 + 5) % (v - 2) + 2
    lens = np.full((b,), prompt_len + 1, np.int32)  # filled positions/row

    logits_ref = jax.jit(lambda p, t: M.logits_fn(p, cfg, t))
    step_jit = jax.jit(lambda p, c, t, s: M.decoder_decode_step(p, cfg, c, t, s))
    full_logits, cache_pairs = jax.jit(
        lambda p, t: M.decoder_prefill(p, cfg, t)
    )(params, jnp.asarray(dec))
    caches = [t for kv_pair in cache_pairs for t in kv_pair]
    # Next-token logits for every row (prefill == decode_logits rescoring).
    rows = np.asarray(full_logits)[np.arange(b), lens - 1]
    max_gap = 0.0
    tokens = [[] for _ in range(b)]
    for _ in range(steps):
        nxt = rows.argmax(-1).astype(np.int32)  # ties -> lowest id, as Rust
        for i in range(b):
            tokens[i].append(int(nxt[i]))
            dec[i, lens[i]] = nxt[i]
        lens = lens + 1
        outs = step_jit(
            params,
            caches,
            jnp.asarray(dec[np.arange(b), lens - 1][:, None]),
            jnp.asarray(lens - 1),
        )
        rows, caches = np.asarray(outs[0]), list(outs[1:])
        full = np.asarray(logits_ref(params, jnp.asarray(dec)))
        gap = float(np.abs(rows - full[np.arange(b), lens - 1]).max())
        max_gap = max(max_gap, gap)
        assert gap < 2e-3, f"{cfg.name}: kv decode diverged from rescoring: {gap}"
    goldens.setdefault(cfg.name, {})["kv_decode"] = {
        "prompt_len": prompt_len,
        "steps": steps,
        "max_abs_logits_gap": max_gap,
        "greedy_tokens": tokens,
    }
    print(f"  kv golden {cfg.name}: max |logits gap| {max_gap:.2e}")


def export_bench(out_dir: str, manifest: dict):
    """Scan vs unrolled lowering at several depths (Scalable T5, E12)."""
    bench = {}
    for depth in (2, 4, 8):
        cfg = dataclasses.replace(
            M.CONFIGS["t5-micro-dec"], num_layers=depth, use_pallas=False
        )
        d, jkv, ff = cfg.d_model, cfg.joined_kv, cfg.d_ff
        stacked = [
            jax.ShapeDtypeStruct((cfg.vocab, d), jnp.float32),  # embed
            jax.ShapeDtypeStruct((cfg.relpos_buckets, cfg.num_heads), jnp.float32),
            jax.ShapeDtypeStruct((depth, d), jnp.float32),  # norm1
            jax.ShapeDtypeStruct((depth, d, jkv), jnp.float32),  # wq
            jax.ShapeDtypeStruct((depth, d, jkv), jnp.float32),  # wk
            jax.ShapeDtypeStruct((depth, d, jkv), jnp.float32),  # wv
            jax.ShapeDtypeStruct((depth, jkv, d), jnp.float32),  # wo
            jax.ShapeDtypeStruct((depth, d), jnp.float32),  # norm2
            jax.ShapeDtypeStruct((depth, d, ff), jnp.float32),  # wi0
            jax.ShapeDtypeStruct((depth, d, ff), jnp.float32),  # wi1
            jax.ShapeDtypeStruct((depth, ff, d), jnp.float32),  # wo2
            jax.ShapeDtypeStruct((d,), jnp.float32),  # final norm
            jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32),
            jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32),
            jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.float32),
        ]
        for kind, fn in (
            ("scan", M.scan_decoder_loss_fn(cfg)),
            ("unroll", M.unrolled_decoder_loss_fn(cfg)),
        ):
            grad_fn = jax.value_and_grad(fn, argnums=tuple(range(12)))
            path = f"bench/{kind}_L{depth}.hlo.txt"
            _write(f"{out_dir}/{path}", to_hlo_text(jax.jit(grad_fn).lower(*stacked)))
            bench[f"{kind}_L{depth}"] = path
        print(f"  bench depth {depth}: scan + unroll exported")
    manifest["bench"] = bench


def export_partdemo(out_dir: str, manifest: dict):
    """Megatron-style MLP sharding demo HLOs (E3): column-parallel w1,
    row-parallel w2; rust all-reduces the partial outputs."""
    mdim, k, f = 64, 256, 1024

    def ffn(x, w1, w2):
        return (jax.nn.gelu(x @ w1, approximate=True) @ w2,)

    demo = {"m": mdim, "k": k, "f": f, "hlos": {}}
    for n in (1, 2, 4):
        fs = f // n
        args = [
            jax.ShapeDtypeStruct((mdim, k), jnp.float32),
            jax.ShapeDtypeStruct((k, fs), jnp.float32),
            jax.ShapeDtypeStruct((fs, k), jnp.float32),
        ]
        name = "ffn_full" if n == 1 else f"ffn_shard{n}"
        path = f"partdemo/{name}.hlo.txt"
        _write(f"{out_dir}/{path}", to_hlo_text(jax.jit(ffn).lower(*args)))
        demo["hlos"][name] = path
    manifest["partdemo"] = demo
    print("  partdemo exported")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--models",
        default="t5-nano-dec,t5-nano-dec-l128,t5-nano-encdec,t5-micro-dec,"
        "t5-micro-encdec,t5-small-dec,t5-100m-dec",
    )
    args = ap.parse_args()
    out = args.out
    manifest = {"format_version": 1, "models": {}}

    t0 = time.time()
    for name in args.models.split(","):
        export_model(M.CONFIGS[name], out, manifest["models"])
    # Model-parallel block entrypoints (§2.2): per-degree segment HLOs +
    # the block_exec collective-schedule contract.
    for name, degrees in BLOCK_DEGREES.items():
        if name in manifest["models"]:
            export_block(M.CONFIGS[name], out, manifest["models"], degrees)
    export_bench(out, manifest)
    export_partdemo(out, manifest)

    goldens = {}
    for name in ("t5-nano-dec", "t5-nano-encdec"):
        if name in manifest["models"]:
            export_golden(M.CONFIGS[name], goldens)
    # Block-vs-monolithic agreement gate (t5-micro is the same lowering at
    # a second size; pattern_params' python-loop init makes it the cutoff).
    for name in ("t5-nano-dec", "t5-micro-dec"):
        if name in manifest["models"] and name in BLOCK_DEGREES:
            export_block_golden(M.CONFIGS[name], BLOCK_DEGREES[name], goldens)
    # Every small decoder export gets the kv-consistency gate — crucially
    # including the long-sequence L=128 config whose serving path leans on
    # the far relpos buckets. (t5-small/t5-100m are skipped only because
    # pattern_params is a per-element python loop; their decode_step HLO
    # is the same lowering checked here at three sizes.)
    for name in ("t5-nano-dec", "t5-nano-dec-l128", "t5-micro-dec"):
        if name in manifest["models"]:
            export_kv_golden(M.CONFIGS[name], goldens)
    _write(f"{out}/golden.json", json.dumps(goldens, indent=1))
    _write(f"{out}/manifest.json", json.dumps(manifest, indent=1))
    print(f"artifacts written to {out} in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
