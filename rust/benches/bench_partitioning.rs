//! E3: the §2.2 strategy matrix, measured — per-host optimizer-state
//! memory, per-step communication bytes, and step time for 1D vs 2D
//! parameter partitioning across data-parallel host counts, plus the
//! analytic GSPMD cost table for the same points.

use t5x::bench::Bench;
use t5x::optim::{OptimizerKind, Schedule};
use t5x::partitioning::cost::{estimate, LinkModel};
use t5x::partitioning::{ActivationStrategy, Mesh, ParamStrategy};
use t5x::runtime::{Artifacts, DeviceHandle};
use t5x::trainer::{BatchSource, Trainer, TrainerConfig};

fn main() {
    let arts = Artifacts::load_default().expect("make artifacts first");
    let device = DeviceHandle::spawn().unwrap();
    let mut bench = Bench::new("partitioning strategies (E3)");
    let model = "t5-nano-dec";
    let m = arts.model(model).unwrap();
    let steps: u64 = if bench.is_quick() { 2 } else { 5 };
    let host_counts: &[usize] = if bench.is_quick() { &[2] } else { &[1, 2, 4] };

    println!(
        "model {model}: {} params | optimizer adam (2 floats/param)\n",
        m.total_params()
    );
    println!(
        "{:<10} {:<6} {:>16} {:>16} {:>14}",
        "strategy", "hosts", "opt floats/host", "comm MiB/step", "tokens/s"
    );
    for &hosts in host_counts {
        for strategy in [ParamStrategy::OneD, ParamStrategy::TwoD] {
            let cfg = TrainerConfig {
                model: model.into(),
                num_hosts: hosts,
                strategy,
                optimizer: OptimizerKind::adam(),
                schedule: Schedule::Constant(1e-3),
                steps,
                seed: 0,
                log_every: 1000,
                checkpoint_every: None,
                checkpoint_dir: None,
        grad_clip_norm: None,
        weight_decay: None,
            };
            let trainer = Trainer::new(&arts, &device, cfg).unwrap();
            let opt_floats = trainer.optimizer_state_floats(0);
            let label = format!("{strategy:?} hosts={hosts}");
            let tokens = (m.tokens_per_step() * hosts * steps as usize) as f64;
            let mes = bench.measure_with_throughput(&label, Some((tokens, "tok")), || {
                let s = trainer.train(&BatchSource::Synthetic { seed: 1 }).unwrap();
                assert!(s.final_loss().is_finite());
            });
            let med = mes.median_s;
            // one fresh run for comm accounting
            let summary = trainer.train(&BatchSource::Synthetic { seed: 1 }).unwrap();
            let comm_mib =
                summary.comm_bytes as f64 / steps as f64 / (1 << 20) as f64;
            println!(
                "{:<10} {:<6} {:>16} {:>16.2} {:>14.0}",
                format!("{strategy:?}"),
                hosts,
                opt_floats,
                comm_mib,
                tokens / med
            );
        }
    }

    // analytic table for the same model (extends to meshes we can't run)
    println!("\nanalytic GSPMD cost model (same model):");
    let meshes = [Mesh::new(1, 1), Mesh::new(2, 1), Mesh::new(4, 1), Mesh::new(16, 1)];
    for mesh in meshes {
        for strategy in [ParamStrategy::OneD, ParamStrategy::TwoD] {
            let e = estimate(m, mesh, strategy, ActivationStrategy::OneD, LinkModel::default());
            println!(
                "  mesh {}x{} {:?}: params {:.2} MiB/host, optim {:.2} MiB/host, comm {:.2} MiB/step",
                mesh.data,
                mesh.model,
                strategy,
                e.param_bytes_per_host as f64 / (1 << 20) as f64,
                e.optim_bytes_per_host as f64 / (1 << 20) as f64,
                e.comm_bytes_per_host as f64 / (1 << 20) as f64
            );
        }
    }
    bench.write_jsonl("bench_results.jsonl").unwrap();
    device.shutdown();
}
