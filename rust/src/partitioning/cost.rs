//! Analytic GSPMD cost model (E3): per-host memory and per-step collective
//! traffic for the paper's §2.2 strategy matrix — 1D/2D parameter
//! partitioning × 1D/2D activation partitioning — on an N = data × model
//! mesh. This regenerates the trade-off table the paper describes in prose,
//! and its communication terms are validated against the *measured* byte
//! counters of [`crate::collectives`] by `bench_partitioning`.
//!
//! The model is execution-mode aware ([`estimate_exec`]): gather mode pays
//! a full-parameter all-gather per model-sharded param every step, block
//! mode replaces that with the activation-sized collective schedule of the
//! block contract — whose per-axis bytes are validated against the
//! *measured* trainer counters by `integration_sharded`.

use super::{ActivationStrategy, ExecMode, Mesh, ParamStrategy};
use crate::runtime::ModelManifest;

/// Memory + communication estimate for one (strategy, mesh) point.
#[derive(Debug, Clone)]
pub struct CostEstimate {
    pub mesh: Mesh,
    pub params: ParamStrategy,
    pub activations: ActivationStrategy,
    /// Per-host bytes of parameters.
    pub param_bytes_per_host: u64,
    /// Per-host bytes of optimizer state (Adam: 2 moments, f32).
    pub optim_bytes_per_host: u64,
    /// Per-host peak activation bytes for one microbatch.
    pub activation_bytes_per_host: u64,
    /// Per-step bytes sent per host over *data-axis* subgroups: gradient
    /// reduce-scatter/all-reduce + (2D) data-axis parameter gather. The
    /// measured counterpart is
    /// `MeshCollectives::axis_bytes(MeshAxis::Data)`.
    pub comm_bytes_data_axis: u64,
    /// Per-step bytes sent per host over *model-axis* subgroups:
    /// parameter all-gather, batch broadcast, and per-layer activation
    /// all-reduces. Measured counterpart:
    /// `MeshCollectives::axis_bytes(MeshAxis::Model)`.
    pub comm_bytes_model_axis: u64,
    /// Per-step collective bytes *sent per host* (both axes).
    pub comm_bytes_per_host: u64,
    /// Of [`Self::comm_bytes_per_host`], the bytes whose transfer rides
    /// under the next microbatch's forward/backward when overlap is on
    /// (the first `k-1` data-axis gradient reduces). Zero with overlap off
    /// or a single microbatch.
    pub comm_bytes_overlapped: u64,
    /// Estimated per-step communication seconds on the link model
    /// (exposed + overlapped).
    pub comm_seconds: f64,
    /// Comm seconds the host actually blocks for. Measured counterpart:
    /// the trainer's `train/exposed_comm_ms` counter.
    pub comm_seconds_exposed: f64,
    /// Comm seconds hidden under compute. Measured counterpart:
    /// `train/overlapped_comm_ms`.
    pub comm_seconds_overlapped: f64,
}

/// How the trainer shapes one step: `microbatches` gradient-accumulation
/// microbatches, with the data-axis reduce of microbatch `j` optionally
/// overlapped with the forward/backward of microbatch `j+1`. Mirrors
/// `TrainerConfig::{microbatches, overlap}`.
#[derive(Debug, Clone, Copy)]
pub struct StepShape {
    pub microbatches: usize,
    pub overlap: bool,
}

impl Default for StepShape {
    fn default() -> Self {
        Self { microbatches: 1, overlap: false }
    }
}

/// Simple α-β link model per host (latency + inverse bandwidth).
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    /// Per-collective latency, seconds.
    pub alpha: f64,
    /// Seconds per byte (1 / bandwidth).
    pub beta: f64,
}

impl Default for LinkModel {
    fn default() -> Self {
        // ~100 GB/s ICI-class link, 10 µs latency.
        Self { alpha: 10e-6, beta: 1.0 / 100e9 }
    }
}

/// Ring collective bytes sent per participant for payload `n` bytes.
pub fn ring_all_reduce_bytes(n: u64, ranks: u64) -> u64 {
    if ranks <= 1 {
        0
    } else {
        2 * n * (ranks - 1) / ranks
    }
}

pub fn ring_all_gather_bytes(full: u64, ranks: u64) -> u64 {
    if ranks <= 1 {
        0
    } else {
        full * (ranks - 1) / ranks
    }
}

pub fn ring_reduce_scatter_bytes(n: u64, ranks: u64) -> u64 {
    if ranks <= 1 {
        0
    } else {
        n * (ranks - 1) / ranks
    }
}

/// Per-host model-axis bytes/step of the block-execution collective
/// schedule: every host-inserted ring reduction the runtime replays (the
/// Megatron f/g activation all-reduces, the four distributed-loss
/// reductions, and the fused replicated-grad sum), with payloads taken
/// from the manifest's per-degree contract — the exact elems the trainer
/// validates its cursor against. `Some(0)` on a 1-wide model axis; `None`
/// when `mesh.model > 1` but the artifacts carry no contract there.
pub fn block_schedule_bytes_per_host(m: &ModelManifest, mesh: Mesh) -> Option<u64> {
    if mesh.model <= 1 {
        return Some(0);
    }
    let spec = m.block_exec(mesh.model)?;
    Some(
        spec.collectives
            .iter()
            .map(|c| ring_all_reduce_bytes(c.elems as u64 * 4, mesh.model as u64))
            .sum(),
    )
}

/// Analytic counterpart of [`block_schedule_bytes_per_host`], derived from
/// the model config alone (no contract needed): `4L+2` residual-stream
/// all-reduces of `B*L*D`, four loss reductions of `B*L`, and one fused
/// `(2L+1)*D` replicated-grad sum. Must agree with the contract payloads
/// exactly (asserted in tests) — this is what extends the cost table to
/// degrees the artifacts were not exported for.
pub fn block_schedule_bytes_analytic(m: &ModelManifest, mesh: Mesh) -> u64 {
    if mesh.model <= 1 {
        return 0;
    }
    let b = m.cfg_usize("batch") as u64;
    let l = m.cfg_usize("seq_len") as u64;
    let d = m.cfg_usize("d_model") as u64;
    let layers = m.cfg_usize("num_layers") as u64;
    let nm = mesh.model as u64;
    let act = (4 * layers + 2) * ring_all_reduce_bytes(b * l * d * 4, nm);
    let loss = 4 * ring_all_reduce_bytes(b * l * 4, nm);
    let repl = ring_all_reduce_bytes((2 * layers + 1) * d * 4, nm);
    act + loss + repl
}

/// Estimate costs for one model/strategy/mesh point at the default
/// (gather) execution mode. See [`estimate_exec`].
pub fn estimate(
    m: &ModelManifest,
    mesh: Mesh,
    params: ParamStrategy,
    activations: ActivationStrategy,
    link: LinkModel,
) -> CostEstimate {
    estimate_exec(m, mesh, params, activations, link, ExecMode::Gather, StepShape::default())
}

/// Estimate costs for one model/strategy/mesh point.
///
/// Model-axis sharding divides parameter storage by `model` (for the
/// shardable fraction; norm scales and small tables stay replicated — we
/// approximate with the exact shardable bytes from the manifest specs).
///
/// `exec` selects the model-axis traffic pattern: `Gather` pays a
/// full-parameter all-gather per model-sharded param every step; `Block`
/// drops those entirely and pays the activation-sized collective schedule
/// instead (`Auto` resolves like the trainer: block iff the manifest
/// carries a contract at `mesh.model`).
///
/// `step` scales the estimate to microbatched steps, mirroring the
/// trainer's execution exactly: the data-axis gradient reduce, the batch
/// broadcast, block mode's shard gathers, and the activation collectives
/// run once *per microbatch*, while gather mode's parameter
/// materialization is hoisted and paid once *per step*. With
/// `step.overlap`, the first `k-1` gradient reduces ride under the next
/// microbatch's compute — their cost moves from
/// [`CostEstimate::comm_seconds_exposed`] to
/// [`CostEstimate::comm_seconds_overlapped`] without changing the total.
pub fn estimate_exec(
    m: &ModelManifest,
    mesh: Mesh,
    params: ParamStrategy,
    activations: ActivationStrategy,
    link: LinkModel,
    exec: ExecMode,
    step: StepShape,
) -> CostEstimate {
    let block = match exec {
        ExecMode::Gather => false,
        ExecMode::Block => true,
        ExecMode::Auto => mesh.model > 1 && m.supports_block_exec(mesh.model),
    };
    let partitioner = super::Partitioner::new(mesh, params);
    // Exact per-host parameter bytes from the per-param specs.
    let mut param_bytes: u64 = 0;
    for p in &m.params {
        let spec = partitioner.spec_for(p);
        let shard_elems: usize = spec.shard_shape(&p.shape).iter().product();
        param_bytes += shard_elems as u64 * 4;
    }
    // Optimizer state (Adam: m + v) lives at the parameter sharding under
    // 2D (ZeRO), but is *replicated per data-parallel rank* under 1D.
    let optim_bytes = 2 * param_bytes;

    // Activation estimate for one layer stack pass (batch B, seq L, d_model
    // D, heads H, ff F): the dominant residual stream + attention + mlp
    // activations, bf16-ish but we count f32 as executed here.
    let b = m.cfg_usize("batch") as u64;
    let l = m.cfg_usize("seq_len") as u64;
    let d = m.cfg_usize("d_model") as u64;
    let f = m.cfg_usize("d_ff") as u64;
    let layers = m.cfg_usize("num_layers") as u64;
    let per_layer = b * l * (2 * d + 2 * f) * 4; // resid + qkv-ish + mlp hidden
    let mut act_bytes = per_layer * layers;
    // model-parallel activations: hidden/heads dims divide by `model`;
    // embed-axis activations divide only under 2D activation sharding.
    if mesh.model > 1 {
        let sharded_fraction = match activations {
            ActivationStrategy::OneD => {
                // hidden (f) shards; embed-axis (d) activations replicated
                (2 * f / mesh.model as u64 + 2 * d) as f64 / (2 * f + 2 * d) as f64
            }
            ActivationStrategy::TwoD => 1.0 / mesh.model as f64,
        };
        act_bytes = (act_bytes as f64 * sharded_fraction) as u64;
    }
    // data parallel batch split
    act_bytes /= mesh.data.max(1) as u64;

    // Communication per step (per host), matching the shard-resident
    // runtime: per parameter, the step-start gather reconstructs the full
    // tensor (data-axis all-gather of the host's block to the model-shard
    // size, then model-axis all-gather to full size), and gradient sync
    // runs over the data axis at the model-shard size (reduce-scatter for
    // data-sharded blocks, all-reduce for data-replicated ones).
    //
    // The terms are accumulated in per-step vs per-microbatch buckets:
    // gather mode hoists parameter materialization out of the microbatch
    // loop (once per step), everything else repeats `k` times.
    let k = step.microbatches.max(1) as u64;
    let mut gather_data: u64 = 0; // param materialization, data axis
    let mut gather_model: u64 = 0; // param materialization, model axis
    let mut sync_data: u64 = 0; // one microbatch's gradient reduce
    let mut mb_model: u64 = 0; // one microbatch's model-axis traffic
    let mut n_gather: u64 = 0;
    let mut n_sync: u64 = 0;
    let mut n_mb_model: u64 = 0;
    for p in &m.params {
        let spec = partitioner.spec_for(p);
        let full_bytes = p.elements() as u64 * 4;
        let model_sharded = spec.dim_for(super::MeshAxis::Model).is_some();
        let data_sharded = spec.dim_for(super::MeshAxis::Data).is_some();
        let model_shard_bytes = if model_sharded {
            full_bytes / mesh.model as u64
        } else {
            full_bytes
        };
        if data_sharded {
            gather_data += ring_all_gather_bytes(model_shard_bytes, mesh.data as u64);
            sync_data += ring_reduce_scatter_bytes(model_shard_bytes, mesh.data as u64);
            n_gather += 1;
            n_sync += 1;
        } else {
            sync_data += ring_all_reduce_bytes(model_shard_bytes, mesh.data as u64);
            n_sync += 1;
        }
        if model_sharded && !block {
            gather_model += ring_all_gather_bytes(full_bytes, mesh.model as u64);
            n_gather += 1;
        }
    }
    // batch broadcast from each data row's leader to its model peers
    // (ring forward: ~full payload per non-terminal host), per microbatch.
    if mesh.model > 1 {
        let batch_bytes: u64 = m
            .batch_features
            .iter()
            .map(|f| f.shape.iter().product::<usize>() as u64 * 4)
            .sum();
        mb_model += batch_bytes * (mesh.model as u64 - 1) / mesh.model as u64;
        n_mb_model += 1;
    }
    // model-parallel activation collectives, per microbatch. Block mode
    // executes the full ordered schedule (contract payloads when exported,
    // the exact analytic formula otherwise); gather mode models the
    // hypothetical GSPMD 2-per-layer all-reduces (the testbed's gather
    // path does not execute these — bench_partitioning only checks
    // direction there).
    if mesh.model > 1 {
        if block {
            mb_model += block_schedule_bytes_per_host(m, mesh)
                .unwrap_or_else(|| block_schedule_bytes_analytic(m, mesh));
            n_mb_model += m
                .block_exec(mesh.model)
                .map(|s| s.collectives.len() as u64)
                .unwrap_or(4 * layers + 7);
        } else {
            mb_model += 2
                * layers
                * ring_all_reduce_bytes(b * l * d * 4 / mesh.data as u64, mesh.model as u64);
            n_mb_model += 2 * layers;
        }
    }
    // Block mode has no hoisted materialization: its data-axis shard
    // gathers run inside every microbatch's block walk.
    let (gather_data_per_step, n_gather_data_per_step) = if block {
        (gather_data * k, n_gather * k)
    } else {
        (gather_data, n_gather)
    };
    let comm_data = gather_data_per_step + sync_data * k;
    let comm_model = gather_model + mb_model * k;
    let comm_total = comm_data + comm_model;
    let n_collectives = n_gather_data_per_step + (n_sync + n_mb_model) * k;
    let comm_seconds = n_collectives as f64 * link.alpha + comm_total as f64 * link.beta;
    // With overlap, the first k-1 gradient reduces ride under the next
    // microbatch's forward/backward; the final reduce (and everything
    // else) stays exposed.
    let sync_seconds =
        (n_sync * k) as f64 * link.alpha + (sync_data * k) as f64 * link.beta;
    let (bytes_overlapped, comm_seconds_overlapped) = if step.overlap && k > 1 {
        (sync_data * (k - 1), sync_seconds * (k - 1) as f64 / k as f64)
    } else {
        (0, 0.0)
    };

    CostEstimate {
        mesh,
        params,
        activations,
        param_bytes_per_host: param_bytes,
        optim_bytes_per_host: optim_bytes,
        activation_bytes_per_host: act_bytes,
        comm_bytes_data_axis: comm_data,
        comm_bytes_model_axis: comm_model,
        comm_bytes_per_host: comm_total,
        comm_bytes_overlapped: bytes_overlapped,
        comm_seconds,
        comm_seconds_exposed: comm_seconds - comm_seconds_overlapped,
        comm_seconds_overlapped,
    }
}

/// Render the full strategy matrix as a markdown table (the E3 artifact).
pub fn strategy_table(m: &ModelManifest, meshes: &[Mesh], link: LinkModel) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "| mesh (DxM) | params | acts | param MiB/host | optim MiB/host | act MiB/host | comm MiB/step/host | comm ms |\n|---|---|---|---|---|---|---|---|\n"
    ));
    for &mesh in meshes {
        for params in [ParamStrategy::OneD, ParamStrategy::TwoD] {
            for acts in [ActivationStrategy::OneD, ActivationStrategy::TwoD] {
                let e = estimate(m, mesh, params, acts, link);
                out.push_str(&format!(
                    "| {}x{} | {:?} | {:?} | {:.2} | {:.2} | {:.2} | {:.2} | {:.3} |\n",
                    mesh.data,
                    mesh.model,
                    params,
                    acts,
                    e.param_bytes_per_host as f64 / (1 << 20) as f64,
                    e.optim_bytes_per_host as f64 / (1 << 20) as f64,
                    e.activation_bytes_per_host as f64 / (1 << 20) as f64,
                    e.comm_bytes_per_host as f64 / (1 << 20) as f64,
                    e.comm_seconds * 1e3,
                ));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Artifacts;

    #[test]
    fn zero3_divides_param_memory() {
        let arts = Artifacts::load_default().unwrap();
        let m = arts.model("t5-micro-dec").unwrap();
        let link = LinkModel::default();
        let base = estimate(m, Mesh::new(1, 1), ParamStrategy::OneD, ActivationStrategy::OneD, link);
        let dp4_1d = estimate(m, Mesh::new(4, 1), ParamStrategy::OneD, ActivationStrategy::OneD, link);
        let dp4_2d = estimate(m, Mesh::new(4, 1), ParamStrategy::TwoD, ActivationStrategy::OneD, link);
        // 1D data parallelism replicates params...
        assert_eq!(dp4_1d.param_bytes_per_host, base.param_bytes_per_host);
        // ...ZeRO-3 shards them ~4x (up to indivisible residue)
        assert!(
            (dp4_2d.param_bytes_per_host as f64)
                < 0.3 * base.param_bytes_per_host as f64,
            "2D {} vs base {}",
            dp4_2d.param_bytes_per_host,
            base.param_bytes_per_host
        );
        // ZeRO trades memory for ~1.5x gradient-sync traffic (RS+AG vs AR
        // at equal full size: (1+1)(n-1)/n vs 2(n-1)/n -> equal, but full
        // here is data*shard so 2D sends no more than ~= 1D; just sanity
        // check both are positive.
        assert!(dp4_1d.comm_bytes_per_host > 0);
        assert!(dp4_2d.comm_bytes_per_host > 0);
    }

    #[test]
    fn model_parallel_reduces_act_memory_2d_more_than_1d() {
        let arts = Artifacts::load_default().unwrap();
        let m = arts.model("t5-micro-dec").unwrap();
        let link = LinkModel::default();
        let a1 = estimate(m, Mesh::new(1, 4), ParamStrategy::OneD, ActivationStrategy::OneD, link);
        let a2 = estimate(m, Mesh::new(1, 4), ParamStrategy::OneD, ActivationStrategy::TwoD, link);
        assert!(a2.activation_bytes_per_host < a1.activation_bytes_per_host);
        // model parallelism costs per-layer all-reduces
        assert!(a1.comm_bytes_per_host > 0);
    }

    #[test]
    fn per_axis_terms_split_by_mesh_axis() {
        let arts = Artifacts::load_default().unwrap();
        let m = arts.model("t5-micro-dec").unwrap();
        let link = LinkModel::default();
        // pure data parallel: all traffic on the data axis
        let dp = estimate(m, Mesh::new(4, 1), ParamStrategy::TwoD, ActivationStrategy::OneD, link);
        assert!(dp.comm_bytes_data_axis > 0);
        assert_eq!(dp.comm_bytes_model_axis, 0);
        // pure model parallel: all traffic on the model axis
        let mp = estimate(m, Mesh::new(1, 4), ParamStrategy::OneD, ActivationStrategy::OneD, link);
        assert_eq!(mp.comm_bytes_data_axis, 0);
        assert!(mp.comm_bytes_model_axis > 0);
        // 2-D: both, and the total is the sum
        let td = estimate(m, Mesh::new(2, 2), ParamStrategy::TwoD, ActivationStrategy::OneD, link);
        assert!(td.comm_bytes_data_axis > 0 && td.comm_bytes_model_axis > 0);
        assert_eq!(
            td.comm_bytes_per_host,
            td.comm_bytes_data_axis + td.comm_bytes_model_axis
        );
    }

    #[test]
    fn block_mode_drops_param_gather_pays_schedule() {
        let arts = Artifacts::load_default().unwrap();
        let m = arts.model("t5-nano-dec").unwrap();
        let link = LinkModel::default();
        let mesh = Mesh::new(1, 2);
        let g = estimate(m, mesh, ParamStrategy::OneD, ActivationStrategy::OneD, link);
        let b = estimate_exec(
            m,
            mesh,
            ParamStrategy::OneD,
            ActivationStrategy::OneD,
            link,
            ExecMode::Block,
            StepShape::default(),
        );
        // identical memory; only the model-axis traffic pattern changes
        assert_eq!(b.param_bytes_per_host, g.param_bytes_per_host);
        assert_eq!(b.comm_bytes_data_axis, g.comm_bytes_data_axis);
        // block = batch broadcast + the exact collective schedule, with no
        // full-parameter all-gather term
        let batch_bytes: u64 = m
            .batch_features
            .iter()
            .map(|f| f.shape.iter().product::<usize>() as u64 * 4)
            .sum();
        let broadcast = batch_bytes * (mesh.model as u64 - 1) / mesh.model as u64;
        assert_eq!(
            b.comm_bytes_model_axis,
            broadcast + block_schedule_bytes_per_host(m, mesh).unwrap()
        );
        // Auto resolves to block exactly when the contract exists
        let a = estimate_exec(
            m,
            mesh,
            ParamStrategy::OneD,
            ActivationStrategy::OneD,
            link,
            ExecMode::Auto,
            StepShape::default(),
        );
        assert_eq!(a.comm_bytes_model_axis, b.comm_bytes_model_axis);
    }

    #[test]
    fn analytic_schedule_matches_exported_contract() {
        let arts = Artifacts::load_default().unwrap();
        let m = arts.model("t5-nano-dec").unwrap();
        for degree in [2usize, 4] {
            let mesh = Mesh::new(1, degree);
            assert_eq!(
                block_schedule_bytes_per_host(m, mesh).unwrap(),
                block_schedule_bytes_analytic(m, mesh),
                "degree {degree}"
            );
        }
        assert_eq!(block_schedule_bytes_per_host(m, Mesh::new(4, 1)), Some(0));
        assert!(block_schedule_bytes_per_host(m, Mesh::new(1, 3)).is_none());
    }

    #[test]
    fn microbatches_scale_per_microbatch_terms_only() {
        let arts = Artifacts::load_default().unwrap();
        let m = arts.model("t5-micro-dec").unwrap();
        let link = LinkModel::default();
        let mesh = Mesh::new(2, 2);
        let mb = |k, overlap| {
            estimate_exec(
                m,
                mesh,
                ParamStrategy::TwoD,
                ActivationStrategy::OneD,
                link,
                ExecMode::Gather,
                StepShape { microbatches: k, overlap },
            )
        };
        let one = mb(1, false);
        let four = mb(4, false);
        // gradient sync repeats 4x but the hoisted param gathers do not:
        // data-axis traffic grows, but by strictly less than 4x...
        assert!(four.comm_bytes_data_axis > one.comm_bytes_data_axis);
        assert!(four.comm_bytes_data_axis < 4 * one.comm_bytes_data_axis);
        // ...and the model-axis param all-gather is paid once per step, so
        // the growth there is only the per-microbatch broadcast +
        // activation terms.
        assert!(four.comm_bytes_model_axis < 4 * one.comm_bytes_model_axis);
        // k=1 is exactly the legacy estimate
        let legacy =
            estimate(m, mesh, ParamStrategy::TwoD, ActivationStrategy::OneD, link);
        assert_eq!(one.comm_bytes_per_host, legacy.comm_bytes_per_host);
        // block mode repeats its shard gathers every microbatch: exact 4x
        // on both axes (no hoisted term on a 1xN mesh's model schedule;
        // use a pure-data mesh so the data axis is everything).
        let dmesh = Mesh::new(4, 1);
        let blk = |k| {
            estimate_exec(
                m,
                dmesh,
                ParamStrategy::TwoD,
                ActivationStrategy::OneD,
                link,
                ExecMode::Block,
                StepShape { microbatches: k, overlap: false },
            )
        };
        assert_eq!(blk(4).comm_bytes_data_axis, 4 * blk(1).comm_bytes_data_axis);
    }

    #[test]
    fn overlap_moves_grad_sync_cost_without_changing_total() {
        let arts = Artifacts::load_default().unwrap();
        let m = arts.model("t5-micro-dec").unwrap();
        let link = LinkModel::default();
        let mesh = Mesh::new(4, 1);
        let e = |k, overlap| {
            estimate_exec(
                m,
                mesh,
                ParamStrategy::TwoD,
                ActivationStrategy::OneD,
                link,
                ExecMode::Gather,
                StepShape { microbatches: k, overlap },
            )
        };
        let serial = e(4, false);
        let over = e(4, true);
        // same bytes and same total seconds either way — overlap only
        // reclassifies where the time goes
        assert_eq!(serial.comm_bytes_per_host, over.comm_bytes_per_host);
        assert!((serial.comm_seconds - over.comm_seconds).abs() < 1e-12);
        assert_eq!(serial.comm_bytes_overlapped, 0);
        assert!(serial.comm_seconds_overlapped == 0.0);
        assert!(over.comm_seconds_overlapped > 0.0);
        assert!(over.comm_seconds_exposed < serial.comm_seconds_exposed);
        assert!(
            (over.comm_seconds_exposed + over.comm_seconds_overlapped
                - over.comm_seconds)
                .abs()
                < 1e-12
        );
        // k=1 has no prior microbatch to hide behind
        let k1 = e(1, true);
        assert_eq!(k1.comm_bytes_overlapped, 0);
        assert!(k1.comm_seconds_overlapped == 0.0);
        // 3 of 4 reduces hide: overlapped bytes are exactly 3x one
        // microbatch's reduce traffic
        let sync_per_mb = (serial.comm_bytes_data_axis
            - e(1, false).comm_bytes_data_axis)
            / 3;
        assert_eq!(over.comm_bytes_overlapped, 3 * sync_per_mb);
    }

    #[test]
    fn table_renders() {
        let arts = Artifacts::load_default().unwrap();
        let m = arts.model("t5-micro-dec").unwrap();
        let t = strategy_table(m, &[Mesh::new(1, 1), Mesh::new(4, 1)], LinkModel::default());
        assert!(t.lines().count() >= 10);
        assert!(t.contains("OneD"));
        assert!(t.contains("TwoD"));
    }
}
