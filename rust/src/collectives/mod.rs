//! Simulated inter-host collectives (S3): the communication layer that XLA
//! GSPMD would emit on a TPU pod, implemented explicitly over threads so
//! the paper's partitioning strategies (§2.2) run with real data movement.
//!
//! [`CollectiveGroup::all_reduce`] / [`CollectiveGroup::reduce_scatter`] /
//! [`CollectiveGroup::all_gather`] are *ring* algorithms: n-1 steps of
//! neighbor exchange moving ~2·(n-1)/n of the payload per participant — the
//! same wire complexity as NCCL/TPU-ICI rings, so measured byte counts match
//! the analytic model in [`crate::partitioning::cost`]. All ranks must call
//! the same ops in the same order (the usual collective contract).
//!
//! ## Axis subgroups ([`MeshCollectives`])
//!
//! A `data × model` [`Mesh`] does not communicate over one flat ring: each
//! collective runs inside a *subgroup* of hosts that share a mesh
//! coordinate — model-axis subgroups (hosts of one data row) carry
//! parameter all-gathers and batch broadcasts, data-axis subgroups (hosts
//! of one model column) carry gradient all-reduce / reduce-scatter.
//! [`MeshCollectives`] owns one [`CollectiveGroup`] ring per subgroup plus
//! a global group for barriers, and accounts bytes/ops *per mesh axis* —
//! the measured counterpart of the per-axis terms in
//! [`crate::partitioning::cost`].
//!
//! The `*_axis` helpers ([`all_gather_axis`], [`reduce_scatter_axis`])
//! lift the flat ring primitives to tensor dimensions: rank `r`'s chunk is
//! its slice along a tensor axis, so a `PartitionSpec`-sharded block can
//! be gathered/reduced along the dimension it is actually sharded on.
//!
//! ## Async collectives ([`CommLane`])
//!
//! Every host owns one [`CommLane`]: a dedicated communication thread that
//! executes submitted ring ops FIFO. [`CollectiveGroup::all_reduce_async`] /
//! [`CollectiveGroup::reduce_scatter_async`] (and the tensor-level
//! [`reduce_scatter_axis_async`] / [`all_reduce_tensor_async`]) enqueue the
//! op and return a [`PendingCollective`] handle immediately, so the host
//! thread keeps computing while the ring steps run on the lane;
//! [`PendingCollective::wait`] joins the result. Because each rank's lane
//! drains in submission order and all ranks submit group ops in the same
//! program order, lane-routed ops keep the usual collective contract.
//!
//! Failure is loud, not a hang: every group created by one
//! [`MeshCollectives`] shares an abort flag. A panicking lane op (or a host
//! thread that unwinds while its lane still holds in-flight ops) sets the
//! flag, and every peer blocked in a ring `recv` notices it and panics
//! (`collective aborted`) instead of waiting forever — so `run_ranks`
//! surfaces the original failure.

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Barrier, Mutex};
use std::time::{Duration, Instant};

use crate::partitioning::{Mesh, MeshAxis};
use crate::runtime::HostTensor;

/// Overall deadline, in ms, for any single ring receive (S10). `0`
/// disables it — the default, so unit tests and ad-hoc runs never race a
/// timer. The training supervisor arms it (`--comm-deadline-ms`, gin
/// `supervisor.comm_deadline_ms`) so a wedged peer becomes a *recoverable
/// failed step*: the stalled receive trips the group's shared abort flag
/// (unsticking every other blocked rank) and panics with the stalled
/// point / axis / rank, which `Trainer::train` surfaces as an `Err`.
static COMM_DEADLINE_MS: AtomicU64 = AtomicU64::new(0);

/// Arm (ms > 0) or disarm (0) the process-wide ring-receive deadline.
pub fn set_comm_deadline_ms(ms: u64) {
    COMM_DEADLINE_MS.store(ms, Ordering::SeqCst);
}

pub fn comm_deadline_ms() -> u64 {
    COMM_DEADLINE_MS.load(Ordering::Relaxed)
}

/// Reduction operator for [`CollectiveGroup::all_reduce_op`]. The block
/// execution schedule (§2.2) needs `Max` (global logit max) and `Min`
/// (argmax claim) besides `Sum`; both are order-independent, so they are
/// exact under any ring schedule, while `Sum` is the usual f32 ring sum.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceOp {
    Sum,
    Max,
    Min,
}

impl ReduceOp {
    #[inline]
    fn apply(self, d: &mut f32, x: f32) {
        match self {
            ReduceOp::Sum => *d += x,
            ReduceOp::Max => *d = d.max(x),
            ReduceOp::Min => *d = d.min(x),
        }
    }
}

/// Per-group transport + accounting shared by all ranks.
pub struct CollectiveGroup {
    n: usize,
    /// senders[r]: rank r's channel to rank (r+1) % n.
    senders: Vec<Sender<Vec<f32>>>,
    /// receivers[r]: rank r's inbox (fed by rank (r-1+n) % n).
    receivers: Vec<Mutex<Receiver<Vec<f32>>>>,
    barrier: Barrier,
    bytes_sent: AtomicU64,
    ops: AtomicU64,
    /// Shared abort flag (see [`CommLane`]): set when any participant's
    /// comm-lane op panics, checked by every blocked ring `recv`.
    abort: Arc<AtomicBool>,
    /// Axis label for deadline diagnostics ("data"/"model"/"global",
    /// set by [`MeshCollectives::new`]; standalone groups report "ring").
    label: std::sync::OnceLock<&'static str>,
    /// Optional span tracer; when attached (and enabled), every multi-rank
    /// ring op records a `coll/*` span with elems/bytes attributes.
    tracer: std::sync::OnceLock<Arc<crate::obs::Tracer>>,
}

impl CollectiveGroup {
    pub fn new(n: usize) -> Arc<CollectiveGroup> {
        Self::new_with_abort(n, Arc::new(AtomicBool::new(false)))
    }

    /// Like [`Self::new`], but sharing an abort flag with sibling groups
    /// (all groups of one [`MeshCollectives`] share one flag, so a failure
    /// on any axis aborts every blocked ring in the mesh).
    pub fn new_with_abort(n: usize, abort: Arc<AtomicBool>) -> Arc<CollectiveGroup> {
        assert!(n >= 1);
        let mut senders = Vec::with_capacity(n);
        let mut receivers_raw: Vec<Option<Receiver<Vec<f32>>>> =
            (0..n).map(|_| None).collect();
        for r in 0..n {
            let (tx, rx) = channel();
            // rank r sends to r+1: the receiver belongs to (r+1) % n
            senders.push(tx);
            receivers_raw[(r + 1) % n] = Some(rx);
        }
        Arc::new(CollectiveGroup {
            n,
            senders,
            receivers: receivers_raw
                .into_iter()
                .map(|r| Mutex::new(r.unwrap()))
                .collect(),
            barrier: Barrier::new(n),
            bytes_sent: AtomicU64::new(0),
            ops: AtomicU64::new(0),
            abort,
            label: std::sync::OnceLock::new(),
            tracer: std::sync::OnceLock::new(),
        })
    }

    /// Name the group's mesh axis for deadline diagnostics (first writer
    /// wins).
    pub fn set_label(&self, label: &'static str) {
        let _ = self.label.set(label);
    }

    fn label(&self) -> &'static str {
        self.label.get().copied().unwrap_or("ring")
    }

    /// The group's shared abort flag — hand this to the [`CommLane`]s of
    /// the ranks that use the group.
    pub fn abort_handle(&self) -> Arc<AtomicBool> {
        self.abort.clone()
    }

    /// Attach a tracer; first writer wins (later calls are no-ops, so
    /// re-attaching the same shared tracer from every host is safe).
    pub fn set_tracer(&self, t: Arc<crate::obs::Tracer>) {
        let _ = self.tracer.set(t);
    }

    /// Per-op span, or None when no tracer is attached/enabled (the
    /// untraced cost is one lock-free `OnceLock::get`).
    fn op_span(&self, name: &'static str, elems: usize) -> Option<crate::obs::Span<'_>> {
        let t = self.tracer.get()?;
        if !t.is_enabled() {
            return None;
        }
        Some(t.span(name).arg("elems", elems).arg("bytes", elems * 4))
    }

    pub fn num_ranks(&self) -> usize {
        self.n
    }

    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent.load(Ordering::Relaxed)
    }

    pub fn ops(&self) -> u64 {
        self.ops.load(Ordering::Relaxed)
    }

    pub fn reset_stats(&self) {
        self.bytes_sent.store(0, Ordering::Relaxed);
        self.ops.store(0, Ordering::Relaxed);
    }

    pub fn barrier(&self, _rank: usize) {
        self.barrier.wait();
    }

    fn send_next(&self, rank: usize, data: Vec<f32>) {
        self.bytes_sent
            .fetch_add(data.len() as u64 * 4, Ordering::Relaxed);
        self.senders[rank].send(data).expect("ring send");
    }

    fn recv_prev(&self, rank: usize, point: &'static str) -> Vec<f32> {
        let rx = self.receivers[rank].lock().unwrap();
        let deadline_ms = comm_deadline_ms();
        let t0 = Instant::now();
        loop {
            if self.abort.load(Ordering::SeqCst) {
                panic!("collective aborted: a peer's comm op failed");
            }
            if deadline_ms > 0 && t0.elapsed().as_millis() as u64 >= deadline_ms {
                // A wedged peer: poison the mesh (unsticking every other
                // blocked rank) and report exactly where the ring stalled.
                self.abort.store(true, Ordering::SeqCst);
                panic!(
                    "collective deadline: {point} on {} axis rank {rank} \
                     stalled > {deadline_ms} ms",
                    self.label()
                );
            }
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(v) => return v,
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => panic!("ring recv: peer hung up"),
            }
        }
    }

    /// Elementwise-sum all-reduce (ring: reduce-scatter + all-gather).
    /// Every rank receives the full reduced vector.
    pub fn all_reduce(&self, rank: usize, data: Vec<f32>) -> Vec<f32> {
        self.all_reduce_op(rank, data, ReduceOp::Sum)
    }

    /// All-reduce under an arbitrary [`ReduceOp`] (same ring schedule as
    /// [`Self::all_reduce`]; only the elementwise combiner changes).
    pub fn all_reduce_op(&self, rank: usize, mut data: Vec<f32>, op: ReduceOp) -> Vec<f32> {
        self.ops.fetch_add(1, Ordering::Relaxed);
        if self.n == 1 {
            return data;
        }
        let _sp = self.op_span("coll/all_reduce", data.len());
        let n = self.n;
        let bounds = chunk_bounds(data.len(), n);
        // Phase 1: reduce-scatter. After n-1 steps rank r owns the fully
        // reduced chunk (r+1) % n.
        for s in 0..n - 1 {
            let send_c = (rank + n - s) % n;
            let (lo, hi) = bounds[send_c];
            self.send_next(rank, data[lo..hi].to_vec());
            let recv_c = (rank + n - s - 1) % n;
            let incoming = self.recv_prev(rank, "coll/all_reduce");
            let (lo, hi) = bounds[recv_c];
            for (d, x) in data[lo..hi].iter_mut().zip(incoming) {
                op.apply(d, x);
            }
        }
        // Phase 2: all-gather of owned chunks.
        for s in 0..n - 1 {
            let send_c = (rank + 1 + n - s) % n;
            let (lo, hi) = bounds[send_c];
            self.send_next(rank, data[lo..hi].to_vec());
            let recv_c = (rank + n - s) % n;
            let incoming = self.recv_prev(rank, "coll/all_reduce");
            let (lo, hi) = bounds[recv_c];
            data[lo..hi].copy_from_slice(&incoming);
        }
        data
    }

    /// Ring reduce-scatter: rank r returns summed chunk r (of n near-equal
    /// contiguous chunks).
    pub fn reduce_scatter(&self, rank: usize, mut data: Vec<f32>) -> Vec<f32> {
        self.ops.fetch_add(1, Ordering::Relaxed);
        let n = self.n;
        let bounds = chunk_bounds(data.len(), n);
        if n == 1 {
            return data;
        }
        let _sp = self.op_span("coll/reduce_scatter", data.len());
        // After n-1 steps of the standard schedule rank r owns chunk
        // (r+1)%n; shift by one so rank r ends owning chunk r.
        for s in 0..n - 1 {
            let send_c = (rank + n - 1 - s) % n;
            let (lo, hi) = bounds[send_c];
            self.send_next(rank, data[lo..hi].to_vec());
            let recv_c = (rank + 2 * n - 2 - s) % n;
            let incoming = self.recv_prev(rank, "coll/reduce_scatter");
            let (lo, hi) = bounds[recv_c];
            for (d, x) in data[lo..hi].iter_mut().zip(incoming) {
                *d += x;
            }
        }
        let (lo, hi) = bounds[rank];
        data[lo..hi].to_vec()
    }

    /// Ring all-gather: each rank contributes chunk `rank` of the conceptual
    /// full vector; every rank returns the concatenation.
    pub fn all_gather(&self, rank: usize, chunk: Vec<f32>, full_len: usize) -> Vec<f32> {
        self.ops.fetch_add(1, Ordering::Relaxed);
        let n = self.n;
        let bounds = chunk_bounds(full_len, n);
        let mut full = vec![0.0f32; full_len];
        let (lo, hi) = bounds[rank];
        debug_assert_eq!(hi - lo, chunk.len(), "rank {rank} chunk size");
        full[lo..hi].copy_from_slice(&chunk);
        if n == 1 {
            return full;
        }
        let _sp = self.op_span("coll/all_gather", full_len);
        for s in 0..n - 1 {
            let send_c = (rank + n - s) % n;
            let (lo, hi) = bounds[send_c];
            self.send_next(rank, full[lo..hi].to_vec());
            let recv_c = (rank + n - 1 - s) % n;
            let incoming = self.recv_prev(rank, "coll/all_gather");
            let (lo, hi) = bounds[recv_c];
            full[lo..hi].copy_from_slice(&incoming);
        }
        full
    }

    /// Broadcast from rank 0 (ring forward).
    pub fn broadcast(&self, rank: usize, data: Option<Vec<f32>>) -> Vec<f32> {
        self.ops.fetch_add(1, Ordering::Relaxed);
        if self.n == 1 {
            return data.expect("root must provide data");
        }
        let _sp =
            self.op_span("coll/broadcast", data.as_ref().map(|d| d.len()).unwrap_or(0));
        if rank == 0 {
            let d = data.expect("root must provide data");
            self.send_next(rank, d.clone());
            d
        } else {
            let d = self.recv_prev(rank, "coll/broadcast");
            if rank != self.n - 1 {
                self.send_next(rank, d.clone());
            }
            d
        }
    }

    /// Nonblocking [`Self::all_reduce`]: the ring runs on `lane`, the
    /// handle joins it. All ranks must submit group ops in the same order.
    pub fn all_reduce_async(
        self: &Arc<Self>,
        lane: &CommLane,
        rank: usize,
        data: Vec<f32>,
    ) -> PendingCollective<Vec<f32>> {
        let g = self.clone();
        lane.submit("lane/all_reduce", move || g.all_reduce(rank, data))
    }

    /// Nonblocking [`Self::reduce_scatter`].
    pub fn reduce_scatter_async(
        self: &Arc<Self>,
        lane: &CommLane,
        rank: usize,
        data: Vec<f32>,
    ) -> PendingCollective<Vec<f32>> {
        let g = self.clone();
        lane.submit("lane/reduce_scatter", move || g.reduce_scatter(rank, data))
    }
}

// ---------------------------------------------------------------------------
// CommLane: the per-host dedicated communication thread
// ---------------------------------------------------------------------------

type LaneJob = Box<dyn FnOnce() + Send>;

/// Per-host communication lane: one worker thread executing submitted ops
/// in FIFO order while the host thread computes. Submission order *is* the
/// rank's collective program order, so routing every concurrently-live
/// group op of a host through its lane preserves the ring contract.
pub struct CommLane {
    tx: Option<Sender<LaneJob>>,
    worker: Option<std::thread::JoinHandle<()>>,
    abort: Arc<AtomicBool>,
    tracer: Arc<std::sync::OnceLock<Arc<crate::obs::Tracer>>>,
}

/// Handle to an op running on a [`CommLane`]. [`Self::wait`] joins it;
/// if the op panicked, `wait` re-panics on the host thread (and the shared
/// abort flag has already unstuck every blocked peer).
pub struct PendingCollective<T> {
    rx: Receiver<Result<(T, u64), String>>,
    label: &'static str,
}

/// Timing of one lane-executed op, as observed by [`PendingCollective::wait_stats`]:
/// `exec_micros` is the op's run time on the lane, `blocked_micros` how long
/// the host thread actually sat in `wait` — the *exposed* part. Their
/// difference is communication hidden behind compute.
#[derive(Clone, Copy, Debug, Default)]
pub struct LaneStats {
    pub exec_micros: u64,
    pub blocked_micros: u64,
}

impl<T> PendingCollective<T> {
    pub fn wait(self) -> T {
        self.wait_stats().0
    }

    pub fn wait_stats(self) -> (T, LaneStats) {
        let t0 = Instant::now();
        match self.rx.recv() {
            Ok(Ok((v, exec_micros))) => (
                v,
                LaneStats { exec_micros, blocked_micros: t0.elapsed().as_micros() as u64 },
            ),
            Ok(Err(msg)) => panic!("comm-lane op {} panicked: {msg}", self.label),
            Err(_) => panic!("comm-lane op {} lost: lane worker died", self.label),
        }
    }
}

fn panic_text(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

impl CommLane {
    /// Spawn a lane whose failures poison `abort` (use the
    /// [`MeshCollectives::abort_handle`] / [`CollectiveGroup::abort_handle`]
    /// of the groups whose ops will run on this lane).
    pub fn new(abort: Arc<AtomicBool>) -> CommLane {
        let (tx, rx) = channel::<LaneJob>();
        let worker = std::thread::Builder::new()
            .name("comm-lane".to_string())
            .spawn(move || {
                while let Ok(job) = rx.recv() {
                    job();
                }
            })
            .expect("spawn comm lane");
        CommLane {
            tx: Some(tx),
            worker: Some(worker),
            abort,
            tracer: Arc::new(std::sync::OnceLock::new()),
        }
    }

    /// Attach a tracer: every submitted op then records a `lane/*` span on
    /// the lane thread (first writer wins, like [`CollectiveGroup::set_tracer`]).
    pub fn set_tracer(&self, t: Arc<crate::obs::Tracer>) {
        let _ = self.tracer.set(t);
    }

    /// Enqueue `f` on the lane; returns immediately. A panic inside `f` is
    /// caught, poisons the shared abort flag (unsticking every peer's ring
    /// recv), and resurfaces when the handle is waited.
    pub fn submit<T: Send + 'static>(
        &self,
        label: &'static str,
        f: impl FnOnce() -> T + Send + 'static,
    ) -> PendingCollective<T> {
        let (rtx, rrx) = channel();
        let abort = self.abort.clone();
        let tracer = self.tracer.clone();
        let job: LaneJob = Box::new(move || {
            let sp = tracer.get().filter(|t| t.is_enabled()).map(|t| t.span(label));
            let t0 = Instant::now();
            let out = std::panic::catch_unwind(AssertUnwindSafe(f));
            let exec_micros = t0.elapsed().as_micros() as u64;
            drop(sp);
            match out {
                Ok(v) => {
                    let _ = rtx.send(Ok((v, exec_micros)));
                }
                Err(p) => {
                    abort.store(true, Ordering::SeqCst);
                    let _ = rtx.send(Err(panic_text(p)));
                }
            }
        });
        self.tx.as_ref().expect("lane closed").send(job).expect("lane worker alive");
        PendingCollective { rx: rrx, label }
    }

    /// Run `f` on the lane and wait for it — same thread routing (and FIFO
    /// position) as an async op, but synchronous to the caller. Returns the
    /// result plus its [`LaneStats`] (here `blocked ≈ exec`).
    pub fn run<T: Send + 'static>(
        &self,
        label: &'static str,
        f: impl FnOnce() -> T + Send + 'static,
    ) -> (T, LaneStats) {
        self.submit(label, f).wait_stats()
    }
}

impl Drop for CommLane {
    fn drop(&mut self) {
        // A host thread unwinding with ops still in flight must not leave
        // peers blocked in ring recvs: poison first, then join the worker
        // (whose in-flight op either completes or aborts loudly).
        if std::thread::panicking() {
            self.abort.store(true, Ordering::SeqCst);
        }
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Split `len` into `n` near-equal contiguous chunks.
pub fn chunk_bounds(len: usize, n: usize) -> Vec<(usize, usize)> {
    let base = len / n;
    let rem = len % n;
    let mut out = Vec::with_capacity(n);
    let mut pos = 0;
    for i in 0..n {
        let sz = base + usize::from(i < rem);
        out.push((pos, pos + sz));
        pos += sz;
    }
    out
}

/// Run `f(rank)` on n threads concurrently and collect results in rank
/// order — the harness used by the trainer and all collective tests/benches.
pub fn run_ranks<T: Send>(n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    crate::util::threads::parallel_map(n, n, f)
}

// ---------------------------------------------------------------------------
// Tensor-axis collectives (the shard-level primitives)
// ---------------------------------------------------------------------------

/// Reorder `full` as the concatenation of its `n` equal slices along
/// `axis` (rank r's slice at chunk r) — the payload layout under which the
/// flat ring chunks coincide with tensor-axis slices.
fn axis_major_payload(full: &HostTensor, axis: usize, n: usize) -> Vec<f32> {
    if axis == 0 || n == 1 {
        return full.as_f32().to_vec(); // axis-0 slices are already contiguous
    }
    let size = full.shape[axis] / n;
    let mut out = Vec::with_capacity(full.elements());
    for r in 0..n {
        out.extend_from_slice(full.slice_axis(axis, r * size, size).as_f32());
    }
    out
}

/// All-gather shards along a tensor `axis`: every rank contributes its
/// slice, every rank returns the full tensor. Pure data movement — the
/// reconstruction is bit-exact.
pub fn all_gather_axis(
    g: &CollectiveGroup,
    rank: usize,
    shard: &HostTensor,
    axis: usize,
) -> HostTensor {
    let n = g.num_ranks();
    if n == 1 {
        return shard.clone();
    }
    let chunk_len = shard.elements();
    let flat = g.all_gather(rank, shard.as_f32().to_vec(), chunk_len * n);
    let mut full_shape = shard.shape.clone();
    full_shape[axis] *= n;
    if axis == 0 {
        return HostTensor::f32(full_shape, flat);
    }
    let slices: Vec<HostTensor> = (0..n)
        .map(|r| {
            HostTensor::f32(shard.shape.clone(), flat[r * chunk_len..(r + 1) * chunk_len].to_vec())
        })
        .collect();
    HostTensor::concat_axis(&slices, axis)
}

/// Reduce-scatter along a tensor `axis`: every rank contributes its local
/// copy of the full tensor; rank r returns the elementwise sum of slice r.
/// For 2 ranks the sum is a single commutative f32 add, so results are
/// bit-identical to any other 2-way summation of the same values.
pub fn reduce_scatter_axis(
    g: &CollectiveGroup,
    rank: usize,
    full: &HostTensor,
    axis: usize,
) -> HostTensor {
    let n = g.num_ranks();
    if n == 1 {
        return full.clone();
    }
    let payload = axis_major_payload(full, axis, n);
    let chunk = g.reduce_scatter(rank, payload);
    let mut shape = full.shape.clone();
    shape[axis] /= n;
    HostTensor::f32(shape, chunk)
}

/// Elementwise-sum all-reduce of a whole tensor (replicated blocks).
pub fn all_reduce_tensor(g: &CollectiveGroup, rank: usize, t: &HostTensor) -> HostTensor {
    all_reduce_tensor_op(g, rank, t, ReduceOp::Sum)
}

/// Tensor all-reduce under an arbitrary [`ReduceOp`] — the host-side g-point
/// primitive of the block execution schedule.
pub fn all_reduce_tensor_op(
    g: &CollectiveGroup,
    rank: usize,
    t: &HostTensor,
    op: ReduceOp,
) -> HostTensor {
    if g.num_ranks() == 1 {
        return t.clone();
    }
    let out = g.all_reduce_op(rank, t.as_f32().to_vec(), op);
    HostTensor::f32(t.shape.clone(), out)
}

/// Nonblocking [`reduce_scatter_axis`]: the gradient-sync primitive the
/// trainer overlaps with the next microbatch's compute.
pub fn reduce_scatter_axis_async(
    g: &Arc<CollectiveGroup>,
    lane: &CommLane,
    rank: usize,
    full: HostTensor,
    axis: usize,
) -> PendingCollective<HostTensor> {
    let g = g.clone();
    lane.submit("lane/reduce_scatter", move || reduce_scatter_axis(&g, rank, &full, axis))
}

/// Nonblocking [`all_reduce_tensor`] (replicated-block gradient sync).
pub fn all_reduce_tensor_async(
    g: &Arc<CollectiveGroup>,
    lane: &CommLane,
    rank: usize,
    t: HostTensor,
) -> PendingCollective<HostTensor> {
    let g = g.clone();
    lane.submit("lane/all_reduce", move || all_reduce_tensor(&g, rank, &t))
}

/// [`all_gather_axis`] routed through the lane *synchronously* — used by
/// block execution so its data-axis shard gathers hold the same FIFO
/// ordering as the in-flight async grad reduces they queue behind.
pub fn all_gather_axis_lane(
    g: &Arc<CollectiveGroup>,
    lane: &CommLane,
    rank: usize,
    shard: &HostTensor,
    axis: usize,
) -> (HostTensor, LaneStats) {
    let g = g.clone();
    let shard = shard.clone();
    lane.run("lane/all_gather", move || all_gather_axis(&g, rank, &shard, axis))
}

/// Broadcast a batch (mixed i32/f32 tensors) from subgroup rank 0 — how a
/// data row's infeed leader shares its batch with its model-axis peers.
/// Non-root ranks pass `None` and learn the shapes from `template`
/// (manifest batch features). Token ids fit f32 exactly (vocab « 2^24),
/// so the i32 round-trip is lossless.
pub fn broadcast_batch(
    g: &CollectiveGroup,
    rank: usize,
    batch: Option<Vec<HostTensor>>,
    template: &[(Vec<usize>, bool)],
) -> Option<Vec<HostTensor>> {
    if g.num_ranks() == 1 {
        return batch;
    }
    // presence flag first so exhaustion propagates to the whole row
    let flag = g.broadcast(
        rank,
        if rank == 0 { Some(vec![batch.is_some() as u8 as f32]) } else { None },
    );
    if flag[0] == 0.0 {
        return None;
    }
    let batch = batch.map(|b| {
        assert_eq!(b.len(), template.len(), "batch/template feature count");
        b
    });
    let mut out = Vec::with_capacity(template.len());
    for (i, (shape, is_int)) in template.iter().enumerate() {
        let payload = batch.as_ref().map(|b| {
            let t = &b[i];
            if *is_int {
                t.as_i32().iter().map(|&x| x as f32).collect()
            } else {
                t.as_f32().to_vec()
            }
        });
        let data = g.broadcast(rank, payload);
        out.push(if *is_int {
            HostTensor::i32(shape.clone(), data.into_iter().map(|x| x as i32).collect())
        } else {
            HostTensor::f32(shape.clone(), data)
        });
    }
    Some(out)
}

// ---------------------------------------------------------------------------
// MeshCollectives: per-axis subgroups + per-axis accounting
// ---------------------------------------------------------------------------

/// The communication fabric of a 2-D mesh: one ring per mesh-axis
/// subgroup, plus a global group for barriers. Byte/op counters aggregate
/// per axis, so benches can attribute traffic to data-parallel gradient
/// sync vs model-parallel parameter movement.
pub struct MeshCollectives {
    pub mesh: Mesh,
    global: Arc<CollectiveGroup>,
    /// Indexed by model coordinate: the `data`-sized ring of one model
    /// column (gradient sync).
    data_groups: Vec<Arc<CollectiveGroup>>,
    /// Indexed by data coordinate: the `model`-sized ring of one data row
    /// (parameter gathers, batch broadcast).
    model_groups: Vec<Arc<CollectiveGroup>>,
    /// One abort flag shared by every group above (and by the hosts'
    /// [`CommLane`]s): any comm failure anywhere aborts the whole mesh.
    abort: Arc<AtomicBool>,
}

impl MeshCollectives {
    pub fn new(mesh: Mesh) -> Arc<MeshCollectives> {
        let abort = Arc::new(AtomicBool::new(false));
        // Fast-path: a 1-wide axis needs no subgroup machinery — all its
        // "subgroups" are one shared degenerate ring (no per-row channel or
        // barrier allocation; every call on it early-returns). `data_group`
        // / `model_group` index accordingly.
        let data_groups = if mesh.data == 1 {
            vec![CollectiveGroup::new_with_abort(1, abort.clone())]
        } else {
            (0..mesh.model)
                .map(|_| CollectiveGroup::new_with_abort(mesh.data, abort.clone()))
                .collect()
        };
        let model_groups = if mesh.model == 1 {
            vec![CollectiveGroup::new_with_abort(1, abort.clone())]
        } else {
            (0..mesh.data)
                .map(|_| CollectiveGroup::new_with_abort(mesh.model, abort.clone()))
                .collect()
        };
        for g in &data_groups {
            g.set_label("data");
        }
        for g in &model_groups {
            g.set_label("model");
        }
        let global = CollectiveGroup::new_with_abort(mesh.num_hosts(), abort.clone());
        global.set_label("global");
        Arc::new(MeshCollectives { mesh, global, data_groups, model_groups, abort })
    }

    /// The mesh-wide abort flag — seed for each host's [`CommLane`].
    pub fn abort_handle(&self) -> Arc<AtomicBool> {
        self.abort.clone()
    }

    pub fn global(&self) -> &CollectiveGroup {
        &self.global
    }

    /// Host's data-axis subgroup and its rank within it (= data coord).
    pub fn data_group(&self, host: usize) -> (&CollectiveGroup, usize) {
        let (d, m) = self.mesh.coords(host);
        (&self.data_groups[if self.mesh.data == 1 { 0 } else { m }], d)
    }

    /// Like [`Self::data_group`], but handing out the owning `Arc` (the
    /// form async submission needs).
    pub fn data_group_arc(&self, host: usize) -> (Arc<CollectiveGroup>, usize) {
        let (d, m) = self.mesh.coords(host);
        (self.data_groups[if self.mesh.data == 1 { 0 } else { m }].clone(), d)
    }

    /// Host's model-axis subgroup and its rank within it (= model coord).
    pub fn model_group(&self, host: usize) -> (&CollectiveGroup, usize) {
        let (d, m) = self.mesh.coords(host);
        (&self.model_groups[if self.mesh.model == 1 { 0 } else { d }], m)
    }

    /// Like [`Self::model_group`], but handing out the owning `Arc`.
    pub fn model_group_arc(&self, host: usize) -> (Arc<CollectiveGroup>, usize) {
        let (d, m) = self.mesh.coords(host);
        (self.model_groups[if self.mesh.model == 1 { 0 } else { d }].clone(), m)
    }

    pub fn barrier(&self, _host: usize) {
        self.global.barrier(0);
    }

    pub fn axis_bytes(&self, axis: MeshAxis) -> u64 {
        self.groups(axis).iter().map(|g| g.bytes_sent()).sum()
    }

    pub fn axis_ops(&self, axis: MeshAxis) -> u64 {
        self.groups(axis).iter().map(|g| g.ops()).sum()
    }

    fn groups(&self, axis: MeshAxis) -> &[Arc<CollectiveGroup>] {
        match axis {
            MeshAxis::Data => &self.data_groups,
            MeshAxis::Model => &self.model_groups,
        }
    }

    /// Total bytes sent over all subgroups (global-group traffic included).
    pub fn bytes_sent(&self) -> u64 {
        self.axis_bytes(MeshAxis::Data) + self.axis_bytes(MeshAxis::Model) + self.global.bytes_sent()
    }

    pub fn reset_stats(&self) {
        self.global.reset_stats();
        for g in self.data_groups.iter().chain(&self.model_groups) {
            g.reset_stats();
        }
    }

    /// Attach one shared tracer to every subgroup (and the global group),
    /// so per-op `coll/*` spans land on whichever host thread runs them.
    pub fn set_tracer(&self, t: &Arc<crate::obs::Tracer>) {
        self.global.set_tracer(t.clone());
        for g in self.data_groups.iter().chain(&self.model_groups) {
            g.set_tracer(t.clone());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_reduce_matches_sum() {
        for n in [1, 2, 3, 4, 8] {
            let g = CollectiveGroup::new(n);
            let len = 103; // ragged
            let outs = run_ranks(n, |r| {
                let data: Vec<f32> = (0..len).map(|i| (r * len + i) as f32).collect();
                g.all_reduce(r, data)
            });
            let expect: Vec<f32> = (0..len)
                .map(|i| (0..n).map(|r| (r * len + i) as f32).sum())
                .collect();
            for (r, out) in outs.iter().enumerate() {
                assert_eq!(out, &expect, "n={n} rank={r}");
            }
        }
    }

    #[test]
    fn all_reduce_op_max_min_are_exact() {
        for n in [2, 3, 4] {
            for (op, pick) in [
                (ReduceOp::Max, f32::max as fn(f32, f32) -> f32),
                (ReduceOp::Min, f32::min as fn(f32, f32) -> f32),
            ] {
                let g = CollectiveGroup::new(n);
                let len = 37; // ragged
                let outs = run_ranks(n, |r| {
                    let data: Vec<f32> =
                        (0..len).map(|i| ((i * 13 + r * 7) % 19) as f32 - 9.0).collect();
                    g.all_reduce_op(r, data, op)
                });
                let expect: Vec<f32> = (0..len)
                    .map(|i| {
                        (0..n)
                            .map(|r| ((i * 13 + r * 7) % 19) as f32 - 9.0)
                            .fold(if op == ReduceOp::Max { f32::MIN } else { f32::MAX }, pick)
                    })
                    .collect();
                for (r, out) in outs.iter().enumerate() {
                    assert_eq!(out, &expect, "n={n} rank={r} op={op:?}");
                }
            }
        }
    }

    #[test]
    fn one_wide_axis_shares_degenerate_group() {
        // mesh.model == 1: all hosts' model "subgroups" are one shared
        // 1-rank ring; calls early-return and move no bytes (fast-path).
        let mc = MeshCollectives::new(Mesh::new(2, 1));
        run_ranks(2, |h| {
            let (mg, mr) = mc.model_group(h);
            assert_eq!(mg.num_ranks(), 1);
            assert_eq!(mr, 0);
            let out = mg.all_reduce(mr, vec![h as f32]);
            assert_eq!(out[0], h as f32);
        });
        assert_eq!(mc.axis_bytes(MeshAxis::Model), 0);
        // and the symmetric case for a 1-wide data axis
        let mc = MeshCollectives::new(Mesh::new(1, 2));
        run_ranks(2, |h| {
            let (dg, dr) = mc.data_group(h);
            assert_eq!(dg.num_ranks(), 1);
            assert_eq!(dr, 0);
        });
        assert_eq!(mc.axis_bytes(MeshAxis::Data), 0);
    }

    #[test]
    fn reduce_scatter_chunks() {
        for n in [2, 3, 4] {
            let g = CollectiveGroup::new(n);
            let len = 64;
            let outs = run_ranks(n, |r| {
                let data: Vec<f32> = (0..len).map(|i| (i + r) as f32).collect();
                g.reduce_scatter(r, data)
            });
            let bounds = chunk_bounds(len, n);
            for (r, out) in outs.iter().enumerate() {
                let (lo, hi) = bounds[r];
                let expect: Vec<f32> = (lo..hi)
                    .map(|i| (0..n).map(|rr| (i + rr) as f32).sum())
                    .collect();
                assert_eq!(out, &expect, "n={n} rank={r}");
            }
        }
    }

    #[test]
    fn all_gather_reassembles() {
        let n = 4;
        let len = 50; // ragged chunks: 13,13,12,12
        let g = CollectiveGroup::new(n);
        let bounds = chunk_bounds(len, n);
        let full_expect: Vec<f32> = (0..len).map(|i| i as f32 * 2.0).collect();
        let outs = run_ranks(n, |r| {
            let (lo, hi) = bounds[r];
            g.all_gather(r, full_expect[lo..hi].to_vec(), len)
        });
        for out in outs {
            assert_eq!(out, full_expect);
        }
    }

    #[test]
    fn reduce_scatter_then_all_gather_equals_all_reduce() {
        let n = 4;
        let len = 128;
        let g1 = CollectiveGroup::new(n);
        let g2 = CollectiveGroup::new(n);
        let make = |r: usize| -> Vec<f32> {
            (0..len).map(|i| ((i * 7 + r * 13) % 23) as f32).collect()
        };
        let ar = run_ranks(n, |r| g1.all_reduce(r, make(r)));
        let rs_ag = run_ranks(n, |r| {
            let chunk = g2.reduce_scatter(r, make(r));
            g2.all_gather(r, chunk, len)
        });
        assert_eq!(ar, rs_ag);
    }

    #[test]
    fn broadcast_from_root() {
        let n = 5;
        let g = CollectiveGroup::new(n);
        let outs = run_ranks(n, |r| {
            g.broadcast(r, if r == 0 { Some(vec![1.0, 2.0, 3.0]) } else { None })
        });
        for out in outs {
            assert_eq!(out, vec![1.0, 2.0, 3.0]);
        }
    }

    #[test]
    fn byte_accounting_positive_and_ring_sized() {
        let n = 4;
        let len = 100;
        let g = CollectiveGroup::new(n);
        run_ranks(n, |r| g.all_reduce(r, vec![1.0; len]));
        // ring all-reduce sends ~2*(n-1)/n of the payload per rank
        let expected_approx = (2 * (n - 1) * len * 4) as u64; // all ranks
        let got = g.bytes_sent();
        assert!(
            got.abs_diff(expected_approx) <= (n * n * 4) as u64,
            "got {got}, expected ~{expected_approx}"
        );
        assert_eq!(g.ops(), n as u64);
    }

    #[test]
    fn axis_collectives_roundtrip_nonzero_axis() {
        // shard a [4, 8] tensor along axis 1 over 4 ranks, gather it back
        let n = 4;
        let g = CollectiveGroup::new(n);
        let full = HostTensor::f32(vec![4, 8], (0..32).map(|i| i as f32).collect());
        let outs = run_ranks(n, |r| {
            let shard = full.slice_axis(1, r * 2, 2);
            all_gather_axis(&g, r, &shard, 1)
        });
        for out in outs {
            assert_eq!(out, full);
        }
        // reduce-scatter along axis 1: rank r gets the summed slice r
        let g2 = CollectiveGroup::new(n);
        let outs = run_ranks(n, |r| {
            let mine = HostTensor::f32(vec![4, 8], vec![(r + 1) as f32; 32]);
            reduce_scatter_axis(&g2, r, &mine, 1)
        });
        for (r, out) in outs.iter().enumerate() {
            assert_eq!(out.shape, vec![4, 2], "rank {r}");
            assert!(out.as_f32().iter().all(|&x| x == 10.0)); // 1+2+3+4
        }
    }

    #[test]
    fn mesh_collectives_account_per_axis() {
        let mesh = Mesh::new(2, 2);
        let mc = MeshCollectives::new(mesh);
        run_ranks(4, |h| {
            let (dg, dr) = mc.data_group(h);
            let a = dg.all_reduce(dr, vec![1.0; 64]);
            let (mg, mr) = mc.model_group(h);
            let t = HostTensor::f32(vec![2, 4], vec![h as f32; 8]);
            let shard = t.slice_axis(1, mr * 2, 2);
            let _ = all_gather_axis(mg, mr, &shard, 1);
            a[0]
        });
        assert!(mc.axis_bytes(MeshAxis::Data) > 0);
        assert!(mc.axis_bytes(MeshAxis::Model) > 0);
        assert_eq!(mc.axis_ops(MeshAxis::Data), 4); // one all_reduce per host
        assert_eq!(mc.axis_ops(MeshAxis::Model), 4);
        assert_eq!(
            mc.bytes_sent(),
            mc.axis_bytes(MeshAxis::Data) + mc.axis_bytes(MeshAxis::Model)
        );
        mc.reset_stats();
        assert_eq!(mc.bytes_sent(), 0);
    }

    #[test]
    fn broadcast_batch_shares_row_batch() {
        let n = 3;
        let g = CollectiveGroup::new(n);
        let template = vec![(vec![2, 4], true), (vec![2, 4], false)];
        let ints = HostTensor::i32(vec![2, 4], (0..8).collect());
        let floats = HostTensor::f32(vec![2, 4], (0..8).map(|i| i as f32).collect());
        let src = vec![ints.clone(), floats.clone()];
        let outs = run_ranks(n, |r| {
            let b = if r == 0 { Some(src.clone()) } else { None };
            broadcast_batch(&g, r, b, &template)
        });
        for out in outs {
            let out = out.expect("batch present");
            assert_eq!(out[0], ints);
            assert_eq!(out[1], floats);
        }
        // exhaustion propagates
        let g2 = CollectiveGroup::new(n);
        let outs = run_ranks(n, |r| broadcast_batch(&g2, r, None, &template));
        assert!(outs.iter().all(|o| o.is_none()));
    }

    #[test]
    fn async_collectives_match_sync_results() {
        let n = 4;
        let len = 103; // ragged
        let g_sync = CollectiveGroup::new(n);
        let g_async = CollectiveGroup::new(n);
        let make = |r: usize| -> Vec<f32> {
            (0..len).map(|i| ((i * 7 + r * 13) % 23) as f32 - 11.0).collect()
        };
        let sync = run_ranks(n, |r| {
            (g_sync.all_reduce(r, make(r)), g_sync.reduce_scatter(r, make(r)))
        });
        let asn = run_ranks(n, |r| {
            let lane = CommLane::new(g_async.abort_handle());
            let ar = g_async.all_reduce_async(&lane, r, make(r));
            let ar = ar.wait();
            let rs = g_async.reduce_scatter_async(&lane, r, make(r));
            (ar, rs.wait())
        });
        assert_eq!(sync, asn);
    }

    #[test]
    fn lane_overlaps_with_host_compute() {
        // Dispatch the reduce, do "compute" on the host thread, then wait:
        // the result must be exact and the handle must report both exec and
        // blocked time.
        let n = 2;
        let g = CollectiveGroup::new(n);
        let outs = run_ranks(n, |r| {
            let lane = CommLane::new(g.abort_handle());
            let pending = g.all_reduce_async(&lane, r, vec![(r + 1) as f32; 64]);
            let mut acc = 0.0f32; // host-side compute while the ring runs
            for i in 0..10_000 {
                acc += (i as f32).sin();
            }
            let (out, stats) = pending.wait_stats();
            assert!(acc.is_finite());
            (out, stats.exec_micros)
        });
        for (out, _exec) in outs {
            assert!(out.iter().all(|&x| x == 3.0)); // 1 + 2
        }
    }

    #[test]
    fn lane_jobs_run_in_submission_order() {
        // Two async ops on the same group submitted back-to-back by every
        // rank must not interleave (FIFO lane = program order).
        let n = 3;
        let g = CollectiveGroup::new(n);
        let outs = run_ranks(n, |r| {
            let lane = CommLane::new(g.abort_handle());
            let a = g.all_reduce_async(&lane, r, vec![r as f32; 8]);
            let b = g.all_reduce_async(&lane, r, vec![10.0; 8]);
            (a.wait()[0], b.wait()[0])
        });
        for (a, b) in outs {
            assert_eq!(a, 3.0); // 0+1+2
            assert_eq!(b, 30.0);
        }
    }

    #[test]
    fn panicking_lane_op_fails_loudly_not_deadlocks() {
        // Rank 0's lane op panics before joining the ring; rank 1 is blocked
        // in a sync all_reduce on the same group. The abort flag must turn
        // both into panics (propagated by run_ranks) instead of a hang.
        let g = CollectiveGroup::new(2);
        let g2 = g.clone();
        let res = std::panic::catch_unwind(AssertUnwindSafe(|| {
            run_ranks(2, |r| {
                if r == 0 {
                    let lane = CommLane::new(g2.abort_handle());
                    let pending =
                        lane.submit("lane/boom", || -> Vec<f32> { panic!("injected failure") });
                    pending.wait() // re-panics with the lane op's message
                } else {
                    g2.all_reduce(r, vec![1.0; 32]) // must abort, not hang
                }
            });
        }));
        assert!(res.is_err(), "both ranks must fail loudly");
    }

    #[test]
    fn host_panic_with_inflight_lane_op_poisons_peers() {
        // Rank 0 dispatches a real reduce and then panics on its host
        // thread without waiting; dropping its CommLane during unwind must
        // poison the group so rank 1's blocked sync op aborts.
        let g = CollectiveGroup::new(2);
        let g2 = g.clone();
        let res = std::panic::catch_unwind(AssertUnwindSafe(|| {
            run_ranks(2, |r| {
                if r == 0 {
                    let lane = CommLane::new(g2.abort_handle());
                    let _pending = g2.all_reduce_async(&lane, r, vec![1.0; 32]);
                    panic!("host-side failure");
                }
                g2.all_reduce(r, vec![1.0; 32]);
                g2.all_reduce(r, vec![2.0; 32]); // rank 0 never joins this one
            });
        }));
        assert!(res.is_err(), "peer must abort instead of hanging");
    }

    #[test]
    fn concurrent_sequences_stay_ordered() {
        // Two back-to-back collectives on the same group must not interleave.
        let n = 3;
        let g = CollectiveGroup::new(n);
        let outs = run_ranks(n, |r| {
            let a = g.all_reduce(r, vec![r as f32; 8]);
            let b = g.all_reduce(r, vec![1.0; 8]);
            (a[0], b[0])
        });
        for (a, b) in outs {
            assert_eq!(a, 3.0); // 0+1+2
            assert_eq!(b, 3.0);
        }
    }
}
