//! Legacy single-file checkpoint format + converter (paper §2.3: models
//! trained with the Mesh-TF T5 codebase "can be read directly by t5x" and
//! "converted to the native t5x format resulting in faster reading").
//!
//! Layout: `legacy.ckpt` =
//! ```text
//! magic "T5LEGACY" | u32 n_params |
//!   per param: u16 name_len | name | u8 rank | u32 dims... | f32 data...
//! ```
//! One sequential stream — no sliced access, no parallel reads; exactly the
//! properties that make the native chunked format faster to restore
//! (validated by `bench_checkpoint`).

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::CheckpointManager;
use crate::model::Params;
use crate::runtime::HostTensor;

const MAGIC: &[u8; 8] = b"T5LEGACY";

pub fn save_legacy(path: &Path, params: &Params) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&(params.len() as u32).to_le_bytes())?;
    for (name, t) in params {
        w.write_all(&(name.len() as u16).to_le_bytes())?;
        w.write_all(name.as_bytes())?;
        w.write_all(&[t.shape.len() as u8])?;
        for &d in &t.shape {
            w.write_all(&(d as u32).to_le_bytes())?;
        }
        for &x in t.as_f32() {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

pub fn load_legacy(path: &Path) -> anyhow::Result<Params> {
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "bad legacy checkpoint magic");
    let mut u32b = [0u8; 4];
    r.read_exact(&mut u32b)?;
    let n = u32::from_le_bytes(u32b) as usize;
    let mut params = Params::new();
    for _ in 0..n {
        let mut u16b = [0u8; 2];
        r.read_exact(&mut u16b)?;
        let name_len = u16::from_le_bytes(u16b) as usize;
        let mut name_bytes = vec![0u8; name_len];
        r.read_exact(&mut name_bytes)?;
        let name = String::from_utf8(name_bytes)?;
        let mut rank = [0u8; 1];
        r.read_exact(&mut rank)?;
        let mut shape = Vec::with_capacity(rank[0] as usize);
        for _ in 0..rank[0] {
            r.read_exact(&mut u32b)?;
            shape.push(u32::from_le_bytes(u32b) as usize);
        }
        let count: usize = shape.iter().product();
        let mut bytes = vec![0u8; count * 4];
        r.read_exact(&mut bytes)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        params.insert(name, HostTensor::f32(shape, data));
    }
    Ok(params)
}

/// Convert a legacy checkpoint into the native chunked format at `step`
/// (the t5x `convert_tf_checkpoint` flow).
pub fn convert_to_native(
    legacy_path: &Path,
    mgr: &CheckpointManager,
    step: u64,
) -> anyhow::Result<usize> {
    let params = load_legacy(legacy_path)?;
    let n = params.len();
    mgr.save(step, &params, &Vec::new())?;
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn legacy_roundtrip_and_convert() {
        let dir = std::env::temp_dir().join(format!("legacy_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut params = Params::new();
        params.insert(
            "w1".into(),
            HostTensor::f32(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]),
        );
        params.insert("scale".into(), HostTensor::f32(vec![2], vec![1.0, 1.0]));
        let path = dir.join("legacy.ckpt");
        save_legacy(&path, &params).unwrap();
        let back = load_legacy(&path).unwrap();
        assert_eq!(back, params);
        // convert and restore natively
        let mgr = CheckpointManager::new(dir.join("native"));
        let n = convert_to_native(&path, &mgr, 0).unwrap();
        assert_eq!(n, 2);
        let (native, _) = mgr.restore(0).unwrap();
        assert_eq!(native, params);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join(format!("legacy_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"NOTLEGACYxxxx").unwrap();
        assert!(load_legacy(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
