"""AOT export contract tests: the manifest/golden/HLO artifacts that the
Rust layer consumes. Runs against a temp export of the nano models (fast)
so the contract is validated even before `make artifacts`."""

import json
import os
import subprocess
import sys

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first")
    with open(path) as f:
        return json.load(f)


def test_manifest_structure(manifest):
    assert manifest["format_version"] == 1
    models = manifest["models"]
    assert "t5-nano-dec" in models
    for name, m in models.items():
        assert m["arch"] in ("decoder", "encdec")
        names = [p["name"] for p in m["params"]]
        assert names == sorted(names), f"{name}: params must be sorted"
        assert len(names) == len(set(names))
        for p in m["params"]:
            assert len(p["shape"]) == len(p["logical_axes"]), p["name"]
            kind = p["init"].split(":")[0]
            assert kind in ("normal", "const")
        eps = m["entrypoints"]
        for ep in ("train_step", "eval_step", "decode_logits"):
            assert ep in eps
            hlo = os.path.join(ART, eps[ep]["hlo"])
            assert os.path.exists(hlo), hlo
        # train outputs = 3 scalars + grads in param order
        outs = eps["train_step"]["outputs"]
        assert outs[:3] == ["loss_sum", "weight_sum", "correct_sum"]
        assert outs[3:] == [f"grad:{n}" for n in names]
        # KV-cached incremental decoding: decoder models export
        # prefill/decode_step and declare the cache contract.
        if m["arch"] == "decoder":
            for ep in ("prefill", "decode_step"):
                assert ep in eps, f"{name}: missing {ep}"
                assert os.path.exists(os.path.join(ART, eps[ep]["hlo"]))
            kv = m["kv_cache"]
            cfg = m["config"]
            assert kv["shape"] == [
                cfg["batch"],
                cfg["num_heads"],
                cfg["seq_len"],
                cfg["head_dim"],
            ]
            assert kv["num_layers"] == cfg["num_layers"]
            assert kv["per_layer"] == ["k", "v"]
            n_cache = 2 * kv["num_layers"]
            assert len(eps["prefill"]["outputs"]) == 1 + n_cache
            assert len(eps["decode_step"]["outputs"]) == 1 + n_cache
            assert eps["decode_step"]["inputs"][-2:] == ["token", "pos"]
        else:
            assert "prefill" not in eps and "kv_cache" not in m


def test_hlo_text_is_parseable_hlo(manifest):
    path = os.path.join(
        ART, manifest["models"]["t5-nano-dec"]["entrypoints"]["train_step"]["hlo"]
    )
    text = open(path).read()
    assert text.startswith("HloModule"), "expected HLO text format"
    assert "ENTRY" in text
    # the interchange constraint: text, not serialized proto (see aot.py)
    assert "\x00" not in text[:1000]


def test_golden_values_consistent(manifest):
    with open(os.path.join(ART, "golden.json")) as f:
        golden = json.load(f)
    for name in ("t5-nano-dec", "t5-nano-encdec"):
        g = golden[name]
        m = manifest["models"][name]
        assert set(g["grad_norms"].keys()) == {p["name"] for p in m["params"]}
        # weight_sum = B*L - 4 masked positions
        b = m["config"]["batch"]
        l = m["config"]["seq_len"]
        assert g["weight_sum"] == b * l - 4
        assert g["loss_sum"] > 0
        # per-token loss near ln(vocab) at pattern init (small-scale init)
        per_tok = g["loss_sum"] / g["weight_sum"]
        import math

        assert abs(per_tok - math.log(m["config"]["vocab"])) < 1.0
    # KV-decode goldens: the exporter asserts prefill + N x decode_step
    # logits match full rescoring (incl. the long-range L=128 config) and
    # records the residual gap.
    for name in ("t5-nano-dec", "t5-nano-dec-l128", "t5-micro-dec"):
        if name not in manifest["models"]:
            continue
        kv = golden[name]["kv_decode"]
        assert kv["max_abs_logits_gap"] < 2e-3, name
        b = manifest["models"][name]["config"]["batch"]
        assert len(kv["greedy_tokens"]) == b, name
        assert all(len(t) == kv["steps"] for t in kv["greedy_tokens"]), name
        l = manifest["models"][name]["config"]["seq_len"]
        assert kv["prompt_len"] >= min(l // 2, l - 8), f"{name}: short prompt"


def test_bench_and_partdemo_artifacts(manifest):
    for key in ("scan_L2", "unroll_L2", "scan_L8", "unroll_L8"):
        assert os.path.exists(os.path.join(ART, manifest["bench"][key]))
    pd = manifest["partdemo"]
    assert pd["f"] % 4 == 0
    for name in ("ffn_full", "ffn_shard2", "ffn_shard4"):
        assert os.path.exists(os.path.join(ART, pd["hlos"][name]))


def test_scan_hlo_constant_in_depth_unroll_grows(manifest):
    """The Scalable T5 claim's static half: scan HLO size is flat in
    depth while unrolled HLO grows with the layer count."""
    size = lambda k: os.path.getsize(os.path.join(ART, manifest["bench"][k]))
    assert size("scan_L8") <= size("scan_L2") * 1.05
    assert size("unroll_L8") > size("unroll_L2") * 2
    assert size("unroll_L8") > size("scan_L8") * 1.5


def test_pattern_init_cross_language_formula():
    """The exact formula mirrored by rust/src/util/rng.rs::pattern_init."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from compile.model import fnv1a64, pattern_init, splitmix64

    # FNV-1a empty-string basis (shared constant with rust tests)
    assert fnv1a64("") == 0xCBF29CE484222325
    v = pattern_init("token_embed", (4,), 0.05, seed=0)
    assert all(abs(x) <= 0.05 for x in v)
    # deterministic
    v2 = pattern_init("token_embed", (4,), 0.05, seed=0)
    assert (v == v2).all()
