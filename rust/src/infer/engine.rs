//! Continuous-batching inference engine (the serving half of t5x's
//! `InferTask` path, grown into a real scheduler).
//!
//! The model's decode HLOs have a fixed batch `B` baked in; naive serving
//! runs one request per full-batch call (1/B slot utilization) or waits
//! for the slowest row of a batch to finish (head-of-line blocking). This
//! engine instead treats the `B` rows as *slots*:
//!
//! * a FIFO queue holds submitted [`InferRequest`]s;
//! * before every decode step, free slots are refilled from the queue —
//!   a request admitted at step `s` starts decoding at step `s` while
//!   longer-running rows continue uninterrupted (continuous batching);
//! * a row that emits EOS or reaches its token budget exits immediately,
//!   freeing its slot for the next queued request at the *next* step, not
//!   at the end of the batch.
//!
//! ## Decode modes: KV-cached vs full rescoring
//!
//! [`DecodeMode::Rescore`] drives the original `decode_logits` HLO: every
//! step re-scores the full `[B, L]` prefix — O(L^2) work per sequence.
//! [`DecodeMode::Kv`] is the O(L) hot path over the `prefill` /
//! `decode_step` entrypoints:
//!
//! * **admit** — freshly admitted slots run `prefill` once: it scores the
//!   whole token buffer and materializes per-layer K/V tensors
//!   (`[B, H, L, head_dim]`, see the manifest `kv_cache` contract). Only
//!   the *fresh* slots' cache rows are copied out of the prefill result
//!   (batch-major layout makes each row one contiguous `copy_from_slice`)
//!   — mid-flight neighbors keep their incrementally built rows, so their
//!   logits stream is bit-identical to an unpacked run;
//! * **step** — continuing slots advance through `decode_step` with a
//!   `[B, 1]` token input (each row's last written token and its
//!   position): one position of attention work per row, the cache row
//!   extended in place;
//! * **retire** — the slot's cache rows go stale and are simply
//!   overwritten by the `prefill` of the next request admitted to that
//!   slot (cache-row recycling; nothing is zeroed).
//!
//! Mode selection: `InferEngine::new` auto-selects Kv when the manifest
//! [`supports_kv_decode`](crate::runtime::artifacts::ModelManifest::supports_kv_decode),
//! falling back to Rescore for stale artifact dirs; `with_mode` (CLI
//! `--decode-mode kv|rescore`) forces either. Both modes produce
//! byte-identical per-request *schedules* (admissions, retirements, step
//! numbering) by construction. Token identity is enforced one level
//! down: `decode_step` is a different lowering of the same math (single-
//! query attention over the cache vs full-prefix rescoring, reference
//! kernels vs the fused ones), and the exporter FAILS unless its logits
//! match full rescoring within a bound (`export_kv_golden`, incl. the
//! long-range relpos buckets at L=128) that sits orders of magnitude
//! below typical argmax margins — so per-slot outputs match rescore
//! mode byte-for-byte (asserted across greedy/sampling/refill schedules
//! by `tests/integration_infer.rs`; a checkpoint whose top-2 logits tie
//! within the kernel gap could in principle flip a token).
//!
//! ## Determinism contract
//!
//! Per-row logits are independent of the other rows' contents (in both
//! modes), greedy tokens come from [`decoding::argmax`] (shared with
//! `EvalRunner::greedy_decode`), and sampling draws exactly one RNG value
//! per token from a per-request [`Pcg64`] — so a request's output is
//! byte-identical whether it ran alone or packed with arbitrary neighbors
//! (asserted by `tests/integration_infer.rs`).
//!
//! Metrics flow through [`crate::metrics::CounterSet`]: `infer/steps`,
//! `infer/tokens`, `infer/requests_completed`, `infer/slot_steps_busy`
//! (utilization = busy / (steps * B)), `infer/refills` (admissions that
//! happened while other requests were mid-flight), and in Kv mode
//! `infer/prefills` / `infer/kv_steps` (device calls per kind).

use std::collections::VecDeque;
use std::time::Instant;

use super::decoding::{self, DecodeMethod, Hypothesis};
use crate::metrics::CounterSet;
use crate::model::Params;
use crate::runtime::artifacts::ModelManifest;
use crate::runtime::{Artifacts, DeviceHandle, Executable, HostTensor};
use crate::util::rng::Pcg64;

/// How the engine drives the model: the O(L) KV-cached incremental path
/// or the original full-rescore path (also the stale-artifact fallback).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeMode {
    /// `prefill` on admit + `decode_step` per token ([B, 1] input).
    Kv,
    /// `decode_logits` over the full [B, L] prefix every step.
    Rescore,
}

impl DecodeMode {
    pub fn name(&self) -> &'static str {
        match self {
            DecodeMode::Kv => "kv",
            DecodeMode::Rescore => "rescore",
        }
    }

    /// Parse a CLI `--decode-mode` value; `auto` (None) lets the engine
    /// pick by manifest capability.
    pub fn parse(s: &str) -> anyhow::Result<Option<DecodeMode>> {
        match s {
            "auto" => Ok(None),
            "kv" => Ok(Some(DecodeMode::Kv)),
            "rescore" => Ok(Some(DecodeMode::Rescore)),
            other => anyhow::bail!("unknown decode mode '{other}' (auto|kv|rescore)"),
        }
    }
}

/// One inference request. `id` is caller-assigned and echoed on the result.
#[derive(Debug, Clone)]
pub struct InferRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_tokens: usize,
    pub method: DecodeMethod,
}

/// Validate a request against a model manifest — the checks
/// [`InferEngine::submit`] enforces, exposed standalone so the serving
/// gateway can reject bad requests at admission (HTTP 400) without
/// routing them to a replica first.
pub fn validate_request(
    manifest: &ModelManifest,
    req: &InferRequest,
) -> anyhow::Result<()> {
    let l = manifest.seq_len();
    anyhow::ensure!(
        req.prompt.len() + 2 <= l,
        "prompt of {} tokens leaves no room to decode (model seq_len {l} \
         needs BOS + prompt + at least one generated position)",
        req.prompt.len(),
    );
    let v = manifest.vocab();
    if let Some(&bad) = req.prompt.iter().find(|&&t| t < 0 || t as usize >= v) {
        anyhow::bail!("prompt token id {bad} outside the model vocabulary 0..{v}");
    }
    anyhow::ensure!(req.max_tokens >= 1, "max_tokens must be >= 1");
    anyhow::ensure!(
        matches!(req.method, DecodeMethod::Greedy | DecodeMethod::Sample { .. }),
        "the continuous-batching engine decodes greedy/sample requests; \
         use beam_decode() for beam search"
    );
    Ok(())
}

/// A completed request.
#[derive(Debug, Clone)]
pub struct InferResult {
    pub id: u64,
    pub prompt_len: usize,
    /// Generated ids (EOS included when it terminated generation).
    pub tokens: Vec<i32>,
    /// Engine step at which the request entered a batch slot.
    pub started_step: u64,
    /// Engine step after which the request left its slot.
    pub finished_step: u64,
    /// Seconds spent queued before a slot freed up.
    pub queue_seconds: f64,
    /// Submit-to-completion wall time in seconds.
    pub latency_seconds: f64,
    /// Submit-to-first-token wall time in seconds (None if the request
    /// produced no tokens).
    pub ttft_seconds: Option<f64>,
}

struct ActiveSlot {
    id: u64,
    prompt_len: usize,
    /// Next decoder position to fill (BOS at 0, prompt at 1..=prompt_len).
    len: usize,
    produced: Vec<i32>,
    max_tokens: usize,
    method: DecodeMethod,
    rng: Option<Pcg64>,
    submitted: Instant,
    admitted: Instant,
    started_step: u64,
    /// Submit-to-first-token latency, set when the first token lands.
    ttft_seconds: Option<f64>,
    /// Admitted this step and not yet prefilled (Kv mode: first token
    /// comes from `prefill` logits, after which the slot rides
    /// `decode_step`). Cleared on the slot's first advance in any mode.
    fresh: bool,
}

/// Aggregate serving statistics derived from the engine counters.
#[derive(Debug, Clone)]
pub struct EngineSummary {
    /// Resolved decode mode ("kv" | "rescore").
    pub mode: &'static str,
    pub steps: u64,
    pub tokens: u64,
    pub completed: u64,
    pub refills: u64,
    /// Kv mode: prefill calls (== admission steps) so far.
    pub prefills: u64,
    /// Mean fraction of batch slots occupied per decode step.
    pub slot_utilization: f64,
    /// Wall time spent inside decode steps.
    pub decode_seconds: f64,
    pub tokens_per_sec: f64,
    /// Mean decode wall time per engine step.
    pub seconds_per_step: f64,
    /// Submit-to-first-token latency percentiles over completed requests
    /// (ms; 0 until any request finishes). Percentiles, not means — the
    /// serving headline is the tail, and a mean hides it.
    pub ttft_ms_p50: f64,
    pub ttft_ms_p99: f64,
    /// Submit-to-completion latency percentiles (ms).
    pub latency_ms_p50: f64,
    pub latency_ms_p99: f64,
    /// Queue-wait (submit → slot admission) percentiles (ms) — the
    /// admission cost the serving gateway adds on top of decode time.
    pub queue_ms_p50: f64,
    pub queue_ms_p99: f64,
}

pub struct InferEngine {
    pub manifest: ModelManifest,
    mode: DecodeMode,
    /// `decode_logits`: the Rescore driver, and the beam-search adapter's
    /// substrate in either mode.
    exe: Executable,
    /// Kv mode only: the `prefill` / `decode_step` executables.
    prefill_exe: Option<Executable>,
    step_exe: Option<Executable>,
    /// Kv mode only: per-layer K/V tensors (`kv_cache` manifest contract,
    /// k then v per layer), batch-major so slot `i`'s cache is row `i` of
    /// every tensor. Rows are recycled: a retired slot's rows sit stale
    /// until the next admission's prefill overwrites them.
    cache: Vec<HostTensor>,
    /// Parameter tensors in manifest order. Arc-backed `HostTensor` makes
    /// the per-step `ordered.clone()` O(num_params) pointer bumps, not a
    /// deep copy of the parameter bytes.
    ordered: Vec<HostTensor>,
    eos_id: i32,
    queue: VecDeque<(InferRequest, Instant)>,
    slots: Vec<Option<ActiveSlot>>,
    /// The shared `[B, L]` decoder token buffer, row per slot. Kept fully
    /// written in both modes (Kv prefill reads it on every admission).
    dec: Vec<i32>,
    steps: u64,
    decode_seconds: f64,
    finished: Vec<InferResult>,
    counters: CounterSet,
    /// Span tracer (`serve/*` taxonomy); `Tracer::off()` unless armed via
    /// [`InferEngine::set_tracer`] — the off path is a no-op.
    tracer: std::sync::Arc<crate::obs::Tracer>,
    /// Record spans only for engine steps in `[a, b)` (`--profile-steps`).
    profile_steps: Option<(u64, u64)>,
    /// Submit-to-first-token / submit-to-completion latency histograms
    /// over completed requests, and queue wait (submit → admission) over
    /// admitted requests. Arc-backed: clones handed out by the
    /// `*_histogram()` getters observe live recording.
    ttft_hist: crate::obs::Histogram,
    latency_hist: crate::obs::Histogram,
    queue_hist: crate::obs::Histogram,
    /// Namespace for this engine's trace tracks/counters (`serve` solo;
    /// `serve/replica<i>` under the gateway so N replicas sharing one
    /// tracer don't interleave their queue/slot timelines).
    trace_label: String,
}

impl InferEngine {
    /// Auto-mode constructor: KV-cached decoding when the artifact dir
    /// exports it, full rescoring otherwise (stale dirs keep working).
    pub fn new(
        arts: &Artifacts,
        device: &DeviceHandle,
        model: &str,
        params: &Params,
        eos_id: i32,
    ) -> anyhow::Result<InferEngine> {
        Self::with_mode(arts, device, model, params, eos_id, None)
    }

    /// Construct with an explicit decode mode (`--decode-mode kv|rescore`);
    /// `None` auto-selects by manifest capability. Requesting `Kv` against
    /// an artifact dir without the incremental entrypoints is an error.
    pub fn with_mode(
        arts: &Artifacts,
        device: &DeviceHandle,
        model: &str,
        params: &Params,
        eos_id: i32,
        mode: Option<DecodeMode>,
    ) -> anyhow::Result<InferEngine> {
        let manifest = arts.model(model)?.clone();
        anyhow::ensure!(
            manifest.arch == "decoder",
            "InferEngine serves decoder-only models; {} is {}",
            model,
            manifest.arch
        );
        let mode = match mode {
            Some(DecodeMode::Kv) => {
                anyhow::ensure!(
                    manifest.supports_kv_decode(),
                    "model {} has no prefill/decode_step entrypoints (stale \
                     artifact dir? re-export, or use --decode-mode rescore)",
                    model
                );
                DecodeMode::Kv
            }
            Some(DecodeMode::Rescore) => DecodeMode::Rescore,
            None if manifest.supports_kv_decode() => DecodeMode::Kv,
            None => DecodeMode::Rescore,
        };
        let (exe, _) = device.compile(&manifest.entrypoint("decode_logits")?.hlo)?;
        let (prefill_exe, step_exe, cache) = if mode == DecodeMode::Kv {
            let (pf, _) = device.compile(&manifest.entrypoint("prefill")?.hlo)?;
            let (st, _) = device.compile(&manifest.entrypoint("decode_step")?.hlo)?;
            let kv = manifest.kv_cache.as_ref().unwrap();
            let cache = (0..kv.num_tensors())
                .map(|_| HostTensor::zeros(kv.shape.clone()))
                .collect();
            (Some(pf), Some(st), cache)
        } else {
            (None, None, Vec::new())
        };
        let ordered = crate::model::params_in_order(&manifest, params);
        let b = manifest.batch();
        let l = manifest.seq_len();
        Ok(InferEngine {
            manifest,
            mode,
            exe,
            prefill_exe,
            step_exe,
            cache,
            ordered,
            eos_id,
            queue: VecDeque::new(),
            slots: (0..b).map(|_| None).collect(),
            dec: vec![0i32; b * l],
            steps: 0,
            decode_seconds: 0.0,
            finished: Vec::new(),
            counters: CounterSet::new(),
            tracer: crate::obs::Tracer::off(),
            profile_steps: None,
            ttft_hist: crate::obs::Histogram::new(),
            latency_hist: crate::obs::Histogram::new(),
            queue_hist: crate::obs::Histogram::new(),
            trace_label: "serve".to_string(),
        })
    }

    /// A replica of this engine for the multi-engine gateway: shares the
    /// compiled executables and Arc-backed parameter tensors (clone =
    /// pointer bumps, not a copy of the weights) but owns private slots,
    /// token buffer, KV cache rows, queue, counters, and histograms — so
    /// N replicas decode concurrently against one set of artifacts with
    /// independent stats. The tracer is shared (one trace shows every
    /// replica); call [`InferEngine::set_trace_label`] to namespace this
    /// replica's tracks.
    pub fn replica(&self) -> InferEngine {
        let b = self.manifest.batch();
        let l = self.manifest.seq_len();
        let cache = match (self.mode, self.manifest.kv_cache.as_ref()) {
            (DecodeMode::Kv, Some(kv)) => (0..kv.num_tensors())
                .map(|_| HostTensor::zeros(kv.shape.clone()))
                .collect(),
            _ => Vec::new(),
        };
        InferEngine {
            manifest: self.manifest.clone(),
            mode: self.mode,
            exe: self.exe.clone(),
            prefill_exe: self.prefill_exe.clone(),
            step_exe: self.step_exe.clone(),
            cache,
            ordered: self.ordered.clone(),
            eos_id: self.eos_id,
            queue: VecDeque::new(),
            slots: (0..b).map(|_| None).collect(),
            dec: vec![0i32; b * l],
            steps: 0,
            decode_seconds: 0.0,
            finished: Vec::new(),
            counters: CounterSet::new(),
            tracer: self.tracer.clone(),
            profile_steps: self.profile_steps,
            ttft_hist: crate::obs::Histogram::new(),
            latency_hist: crate::obs::Histogram::new(),
            queue_hist: crate::obs::Histogram::new(),
            trace_label: self.trace_label.clone(),
        }
    }

    /// Namespace this engine's trace tracks and counters (the gateway
    /// sets `serve/replica<i>`; default `serve`).
    pub fn set_trace_label(&mut self, label: impl Into<String>) {
        self.trace_label = label.into();
    }

    /// Arm span recording (`serve/*` spans, per-request tracks, queue/slot
    /// counters). The engine holds `Tracer::off()` otherwise.
    pub fn set_tracer(&mut self, tracer: std::sync::Arc<crate::obs::Tracer>) {
        self.tracer = tracer;
    }

    /// Limit span recording to engine steps in `[a, b)`.
    pub fn set_profile_steps(&mut self, window: Option<(u64, u64)>) {
        self.profile_steps = window;
    }

    pub fn tracer(&self) -> &std::sync::Arc<crate::obs::Tracer> {
        &self.tracer
    }

    /// The resolved decode mode this engine runs with.
    pub fn mode(&self) -> DecodeMode {
        self.mode
    }

    pub fn eos_id(&self) -> i32 {
        self.eos_id
    }

    /// Enqueue a request. `max_tokens` is clamped to the sequence budget
    /// (`seq_len - 1 - prompt_len`); over-long prompts and out-of-vocab
    /// token ids are rejected *here* — the serve loop turns the error into
    /// a per-request response instead of crashing mid-decode.
    pub fn submit(&mut self, req: InferRequest) -> anyhow::Result<()> {
        validate_request(&self.manifest, &req)?;
        self.counters.inc("infer/requests_submitted");
        self.queue.push_back((req, Instant::now()));
        Ok(())
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || self.slots.iter().any(|s| s.is_some())
    }

    /// Pull queued requests into free slots (continuous-batching refill).
    fn admit(&mut self) {
        let l = self.manifest.seq_len();
        for i in 0..self.slots.len() {
            if self.slots[i].is_some() {
                continue;
            }
            let Some((req, submitted)) = self.queue.pop_front() else {
                break;
            };
            // A *refill* is an admission while other requests are already
            // mid-decode (have produced tokens) — i.e. this request joins
            // a running batch rather than a fresh one.
            let mid_flight =
                self.slots.iter().flatten().any(|s| !s.produced.is_empty());
            if mid_flight {
                self.counters.inc("infer/refills");
            }
            let plen = req.prompt.len();
            let max_tokens = req.max_tokens.min(l - 1 - plen);
            let row = &mut self.dec[i * l..(i + 1) * l];
            row.fill(0);
            row[1..=plen].copy_from_slice(&req.prompt);
            let rng = match &req.method {
                DecodeMethod::Sample { seed, .. } => Some(Pcg64::new(*seed)),
                _ => None,
            };
            let admitted = Instant::now();
            self.queue_hist.record_seconds((admitted - submitted).as_secs_f64());
            self.slots[i] = Some(ActiveSlot {
                id: req.id,
                prompt_len: plen,
                len: plen + 1,
                produced: Vec::new(),
                max_tokens,
                method: req.method,
                rng,
                submitted,
                admitted,
                started_step: self.steps,
                ttft_seconds: None,
                fresh: true,
            });
        }
    }

    /// Run one decode step over all occupied slots: admit from the queue,
    /// execute the mode's decode computation(s), extend every active row
    /// by one token, and retire rows that hit EOS / their budget / the
    /// sequence end. Returns the number of rows that decoded (0 = idle).
    ///
    /// The scheduling contract (admission points, one token per active
    /// slot per step, retirement timing) is identical in both modes, so
    /// `started_step`/`finished_step` — and the produced tokens — do not
    /// depend on the decode mode.
    pub fn step(&mut self) -> anyhow::Result<usize> {
        if let Some((a, b)) = self.profile_steps {
            if self.tracer.is_armed() {
                self.tracer.set_enabled(self.steps >= a && self.steps < b);
            }
        }
        self.admit();
        let active = self.active();
        if self.tracer.is_enabled() {
            self.tracer.counter(
                &format!("{}/queue_depth", self.trace_label),
                self.queue.len() as f64,
            );
            self.tracer.counter(
                &format!("{}/active_slots", self.trace_label),
                active as f64,
            );
        }
        if active == 0 {
            return Ok(0);
        }
        match self.mode {
            DecodeMode::Rescore => self.step_rescore(active),
            DecodeMode::Kv => self.step_kv(active),
        }
    }

    /// Extend slot `i` by one token chosen from `row` (`[V]` next-token
    /// logits) and retire it if finished — the mode-independent half of a
    /// decode step (token selection, budget math, bookkeeping).
    fn advance_slot(&mut self, i: usize, row: &[f32]) {
        let l = self.manifest.seq_len();
        let Some(slot) = self.slots[i].as_mut() else {
            return;
        };
        slot.fresh = false;
        let tok = decoding::next_token(&slot.method, row, slot.rng.as_mut()) as i32;
        slot.produced.push(tok);
        if slot.produced.len() == 1 {
            let t = slot.submitted.elapsed().as_secs_f64();
            slot.ttft_seconds = Some(t);
            self.ttft_hist.record_seconds(t);
        }
        self.counters.inc("infer/tokens");
        let done =
            tok == self.eos_id || slot.len + 1 >= l || slot.produced.len() >= slot.max_tokens;
        if done {
            let slot = self.slots[i].take().unwrap();
            self.dec[i * l..(i + 1) * l].fill(0);
            let now = Instant::now();
            self.counters.inc("infer/requests_completed");
            let latency = (now - slot.submitted).as_secs_f64();
            self.latency_hist.record_seconds(latency);
            if self.tracer.is_enabled() {
                use crate::obs::ArgValue;
                // Request lifecycle as two complete events on virtual
                // tracks: the queue wait, then the slot residency.
                self.tracer.complete(
                    &format!("{}/queue", self.trace_label),
                    format!("req {} queued", slot.id),
                    slot.submitted,
                    slot.admitted,
                    vec![("id", ArgValue::Num(slot.id as f64))],
                );
                self.tracer.complete(
                    &format!("{}/slot{i}", self.trace_label),
                    format!("req {}", slot.id),
                    slot.admitted,
                    now,
                    vec![
                        ("id", ArgValue::Num(slot.id as f64)),
                        ("prompt_len", ArgValue::Num(slot.prompt_len as f64)),
                        ("tokens", ArgValue::Num(slot.produced.len() as f64)),
                    ],
                );
            }
            self.finished.push(InferResult {
                id: slot.id,
                prompt_len: slot.prompt_len,
                tokens: slot.produced,
                started_step: slot.started_step,
                finished_step: self.steps,
                queue_seconds: (slot.admitted - slot.submitted).as_secs_f64(),
                latency_seconds: latency,
                ttft_seconds: slot.ttft_seconds,
            });
        } else {
            self.dec[i * l + slot.len] = tok;
            slot.len += 1;
        }
    }

    /// Full-rescore step: one `decode_logits` call over the `[B, L]`
    /// buffer; every row reads its logits at the last filled position.
    fn step_rescore(&mut self, active: usize) -> anyhow::Result<usize> {
        let b = self.manifest.batch();
        let l = self.manifest.seq_len();
        let v = self.manifest.vocab();
        let t0 = Instant::now();
        let sp = self.tracer.span("serve/rescore_step").arg("rows", active);
        let mut inputs = self.ordered.clone();
        inputs.push(HostTensor::i32(vec![b, l], self.dec.clone()));
        let outs = self.exe.run(inputs)?;
        drop(sp); // span must end before advance_slot re-borrows self
        self.decode_seconds += t0.elapsed().as_secs_f64();
        self.steps += 1;
        self.counters.inc("infer/steps");
        self.counters.add("infer/slot_steps_busy", active as u64);
        let lf = outs[0].as_f32(); // [B, L, V]
        for i in 0..b {
            // logits at the last filled position predict the next token
            let pos = match self.slots[i].as_ref() {
                Some(slot) => slot.len - 1,
                None => continue,
            };
            self.advance_slot(i, &lf[(i * l + pos) * v..(i * l + pos + 1) * v]);
        }
        Ok(active)
    }

    /// KV-cached step: continuing slots ride `decode_step` ([B, 1] token
    /// input, one position of attention work per row); freshly admitted
    /// slots run `prefill` once and take their first token from its
    /// logits, with ONLY their cache rows merged out of the prefill
    /// result — mid-flight neighbors keep their incrementally built rows,
    /// so packing/refill schedules cannot perturb a request's logits.
    fn step_kv(&mut self, active: usize) -> anyhow::Result<usize> {
        let b = self.manifest.batch();
        let l = self.manifest.seq_len();
        let v = self.manifest.vocab();
        let cont: Vec<usize> = (0..b)
            .filter(|&i| matches!(self.slots[i].as_ref(), Some(s) if !s.fresh))
            .collect();
        let fresh: Vec<usize> = (0..b)
            .filter(|&i| matches!(self.slots[i].as_ref(), Some(s) if s.fresh))
            .collect();
        let t0 = Instant::now();
        // Continuing rows: the O(1)-per-token hot path. Inactive/fresh
        // rows ride along as (token 0, pos 0); their cache writes are
        // garbage but either unused (empty slots, recycled on the next
        // admission) or overwritten by the prefill merge below.
        let mut step_logits: Option<HostTensor> = None; // [B, V]
        if !cont.is_empty() {
            let _sp = self.tracer.span("serve/decode_step").arg("rows", cont.len());
            let mut tok = vec![0i32; b];
            let mut pos = vec![0i32; b];
            for &i in &cont {
                let s = self.slots[i].as_ref().unwrap();
                tok[i] = self.dec[i * l + s.len - 1];
                pos[i] = (s.len - 1) as i32;
            }
            let mut inputs = self.ordered.clone();
            inputs.extend(self.cache.iter().cloned());
            inputs.push(HostTensor::i32(vec![b, 1], tok));
            inputs.push(HostTensor::i32(vec![b], pos));
            let mut outs = self.step_exe.as_ref().unwrap().run(inputs)?;
            self.cache = outs.split_off(1);
            step_logits = outs.pop();
            self.counters.inc("infer/kv_steps");
        }
        // Fresh rows: one prefill over the shared token buffer, merging
        // only their (contiguous, batch-major) cache rows.
        let mut prefill_logits: Option<HostTensor> = None; // [B, L, V]
        if !fresh.is_empty() {
            let _sp = self.tracer.span("serve/prefill").arg("rows", fresh.len());
            let mut inputs = self.ordered.clone();
            inputs.push(HostTensor::i32(vec![b, l], self.dec.clone()));
            let mut outs = self.prefill_exe.as_ref().unwrap().run(inputs)?;
            let new_cache = outs.split_off(1);
            let row = self.manifest.kv_cache.as_ref().unwrap().row_elements();
            for (dst, src) in self.cache.iter_mut().zip(&new_cache) {
                let d = dst.as_f32_mut();
                let s = src.as_f32();
                for &i in &fresh {
                    d[i * row..(i + 1) * row].copy_from_slice(&s[i * row..(i + 1) * row]);
                }
            }
            prefill_logits = outs.pop();
            self.counters.inc("infer/prefills");
        }
        self.decode_seconds += t0.elapsed().as_secs_f64();
        self.steps += 1;
        self.counters.inc("infer/steps");
        self.counters.add("infer/slot_steps_busy", active as u64);
        for i in 0..b {
            let (was_fresh, pos) = match self.slots[i].as_ref() {
                Some(slot) => (slot.fresh, slot.len - 1),
                None => continue,
            };
            if was_fresh {
                let lf =
                    prefill_logits.as_ref().expect("fresh slot without prefill").as_f32();
                self.advance_slot(i, &lf[(i * l + pos) * v..(i * l + pos + 1) * v]);
            } else {
                let lf = step_logits
                    .as_ref()
                    .expect("continuing slot without decode_step")
                    .as_f32();
                self.advance_slot(i, &lf[i * v..(i + 1) * v]);
            }
        }
        Ok(active)
    }

    /// Step until queue and slots are empty; returns everything completed
    /// since the last drain, in completion order.
    pub fn run_until_idle(&mut self) -> anyhow::Result<Vec<InferResult>> {
        while self.has_work() {
            self.step()?;
        }
        Ok(self.drain_finished())
    }

    /// Take completed results accumulated so far (completion order).
    pub fn drain_finished(&mut self) -> Vec<InferResult> {
        std::mem::take(&mut self.finished)
    }

    /// Beam search for a single request, using the batch rows as beam
    /// slots. Requires an idle engine (beams borrow the whole batch) and
    /// `beams <= B`. Always drives the full-rescore `decode_logits`
    /// executable — beams fork/reorder prefixes every round, which has no
    /// per-slot cache locality — so it works identically in either decode
    /// mode (the "beam fallback").
    pub fn beam_decode(
        &mut self,
        prompt: &[i32],
        beams: usize,
        alpha: f32,
        max_tokens: usize,
    ) -> anyhow::Result<Vec<Hypothesis>> {
        anyhow::ensure!(
            !self.has_work(),
            "beam_decode needs an idle engine (beams occupy every slot)"
        );
        let b = self.manifest.batch();
        let l = self.manifest.seq_len();
        let v = self.manifest.vocab();
        anyhow::ensure!(beams >= 1 && beams <= b, "need 1 <= beams <= batch ({b})");
        anyhow::ensure!(prompt.len() + 2 <= l, "prompt leaves no room to decode");
        let plen = prompt.len();
        let max_tokens = max_tokens.min(l - 1 - plen).max(1);
        let exe = self.exe.clone();
        let ordered = self.ordered.clone();
        let counters = self.counters.clone();
        let step = move |prefixes: &[Vec<i32>]| -> anyhow::Result<Vec<Vec<f32>>> {
            anyhow::ensure!(prefixes.len() <= b, "live beams exceed batch");
            let mut dec = vec![0i32; b * l];
            for (r, pre) in prefixes.iter().enumerate() {
                dec[r * l + 1..r * l + 1 + plen].copy_from_slice(prompt);
                for (j, &t) in pre.iter().enumerate() {
                    dec[r * l + 1 + plen + j] = t;
                }
            }
            let mut inputs = ordered.clone();
            inputs.push(HostTensor::i32(vec![b, l], dec));
            let outs = exe.run(inputs)?;
            let lf = outs[0].as_f32();
            counters.inc("infer/beam_steps");
            // all live prefixes share one length by beam_search's contract
            let pos = plen + prefixes[0].len();
            Ok(prefixes
                .iter()
                .enumerate()
                .map(|(r, _)| lf[(r * l + pos) * v..(r * l + pos + 1) * v].to_vec())
                .collect())
        };
        decoding::beam_search(step, beams, max_tokens, self.eos_id, alpha)
    }

    pub fn counters(&self) -> &CounterSet {
        &self.counters
    }

    /// Mean slot occupancy over all decode steps so far.
    pub fn slot_utilization(&self) -> f64 {
        let steps = self.counters.get("infer/steps");
        if steps == 0 {
            return 0.0;
        }
        self.counters.get("infer/slot_steps_busy") as f64
            / (steps * self.manifest.batch() as u64) as f64
    }

    pub fn summary(&self) -> EngineSummary {
        let tokens = self.counters.get("infer/tokens");
        let steps = self.counters.get("infer/steps");
        EngineSummary {
            mode: self.mode.name(),
            steps,
            tokens,
            completed: self.counters.get("infer/requests_completed"),
            refills: self.counters.get("infer/refills"),
            prefills: self.counters.get("infer/prefills"),
            slot_utilization: self.slot_utilization(),
            decode_seconds: self.decode_seconds,
            tokens_per_sec: if self.decode_seconds > 0.0 {
                tokens as f64 / self.decode_seconds
            } else {
                0.0
            },
            seconds_per_step: if steps > 0 {
                self.decode_seconds / steps as f64
            } else {
                0.0
            },
            ttft_ms_p50: self.ttft_hist.p50(),
            ttft_ms_p99: self.ttft_hist.p99(),
            latency_ms_p50: self.latency_hist.p50(),
            latency_ms_p99: self.latency_hist.p99(),
            queue_ms_p50: self.queue_hist.p50(),
            queue_ms_p99: self.queue_hist.p99(),
        }
    }

    /// Live submit-to-first-token histogram (Arc-backed clone observes
    /// ongoing recording — the gateway's `/metrics` reads it while this
    /// engine steps on its replica thread).
    pub fn ttft_histogram(&self) -> &crate::obs::Histogram {
        &self.ttft_hist
    }

    /// Live submit-to-completion latency histogram.
    pub fn latency_histogram(&self) -> &crate::obs::Histogram {
        &self.latency_hist
    }

    /// Live queue-wait (submit → slot admission) histogram.
    pub fn queue_histogram(&self) -> &crate::obs::Histogram {
        &self.queue_hist
    }

    /// Flush serving latency histograms as metric points (`serve/ttft_ms_*`,
    /// `serve/latency_ms_*`, `serve/queue_ms_*` p50/p95/p99/mean/count).
    pub fn log_latency_to(&self, logger: &crate::metrics::MetricsLogger, step: u64) {
        self.ttft_hist.log_to(logger, step, "serve/ttft_ms");
        self.latency_hist.log_to(logger, step, "serve/latency_ms");
        self.queue_hist.log_to(logger, step, "serve/queue_ms");
    }
}
