//! The deterministic cache job (paper §3.2): "a distributed caching job
//! loads the raw data, preprocesses and shuffles the examples, assigns
//! ordered indices, and writes the data to sharded files. Importantly, the
//! examples are sharded by the modulo of their index to the number of
//! files."
//!
//! This is the Apache-Beam substitute: multi-threaded over shard writers,
//! one pass, deterministic given the seed. The resulting layout is read by
//! [`super::deterministic`].
//!
//! Two directory layouts exist:
//!
//! * **single-split** ([`cache_task`], the original layout): shard files +
//!   `cache_meta.json` at the root, holding one split (train);
//! * **multi-split** ([`cache_task_splits`]): every split of the task
//!   cached under `splits/<name>/` (each subdirectory is itself a valid
//!   single-split cache), with a root `cache_meta.json` listing the split
//!   names. [`crate::seqio::provider::CachedTask`] opens either layout and
//!   serves each cached split through `get_dataset`.

use std::path::{Path, PathBuf};

use super::provider::DatasetProvider;
use super::records::RecordWriter;
use super::serialize_example;
use super::task::Task;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::threads::parallel_map;

#[derive(Clone, Debug)]
pub struct CacheConfig {
    /// Number of output record files. Choose a multiple of every host count
    /// you intend to train with (paper: enables exclusive file sets).
    pub num_shards: usize,
    /// Shuffle / preprocessing seed.
    pub seed: u64,
    /// Writer threads.
    pub workers: usize,
}

impl Default for CacheConfig {
    fn default() -> Self {
        Self { num_shards: 8, seed: 0, workers: 4 }
    }
}

#[derive(Debug, Clone)]
pub struct CacheMeta {
    pub task: String,
    pub num_examples: usize,
    pub num_shards: usize,
    pub seed: u64,
    /// The split this directory holds ("train" for legacy roots).
    pub split: String,
    /// Multi-split root: names cached under `splits/<name>/`. None for a
    /// single-split directory (shard files at this level).
    pub splits: Option<Vec<String>>,
}

impl CacheMeta {
    pub fn shard_file(dir: &Path, shard: usize) -> PathBuf {
        dir.join(format!("shard-{shard:05}.rec"))
    }

    /// Subdirectory of a multi-split cache holding one split.
    pub fn split_dir(dir: &Path, split: &str) -> PathBuf {
        dir.join("splits").join(split)
    }

    pub fn load(dir: &Path) -> anyhow::Result<CacheMeta> {
        let j = Json::parse_file(dir.join("cache_meta.json"))?;
        Ok(CacheMeta {
            task: j.get("task").and_then(|v| v.as_str()).unwrap_or("").to_string(),
            num_examples: j
                .get("num_examples")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow::anyhow!("cache_meta missing num_examples"))?,
            num_shards: j
                .get("num_shards")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow::anyhow!("cache_meta missing num_shards"))?,
            seed: j.get("seed").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
            split: j
                .get("split")
                .and_then(|v| v.as_str())
                .unwrap_or("train")
                .to_string(),
            splits: j.get("splits").and_then(|v| v.as_arr()).map(|a| {
                a.iter()
                    .filter_map(|x| x.as_str().map(|s| s.to_string()))
                    .collect()
            }),
        })
    }

    fn save(&self, dir: &Path) -> anyhow::Result<()> {
        let mut pairs = vec![
            ("task", Json::str(self.task.clone())),
            ("num_examples", Json::num(self.num_examples as f64)),
            ("num_shards", Json::num(self.num_shards as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("split", Json::str(self.split.clone())),
        ];
        if let Some(splits) = &self.splits {
            pairs.push((
                "splits",
                Json::Arr(splits.iter().map(|s| Json::str(s.clone())).collect()),
            ));
        }
        std::fs::write(dir.join("cache_meta.json"), Json::obj(pairs).to_string())?;
        Ok(())
    }
}

/// Cache one split of a task into `dir` (shard files + per-dir metadata;
/// no atomicity — callers stage into a tmp root and rename).
fn write_split(
    task: &Task,
    split: &str,
    dir: &Path,
    cfg: &CacheConfig,
) -> anyhow::Result<CacheMeta> {
    std::fs::create_dir_all(dir)?;

    // 1. materialize the preprocessed split (the "Beam" load+preprocess).
    let mut examples = task.dataset_split(split, cfg.seed, 0, 1)?.collect_vec();
    anyhow::ensure!(
        !examples.is_empty(),
        "task '{}' split '{split}' produced no examples",
        task.name
    );
    for ex in examples.iter().take(8) {
        task.validate_example(ex)?;
    }

    // 2. global shuffle (the well-shuffled guarantee of §3.2).
    let mut rng = Pcg64::new(cfg.seed ^ 0x5348_5546); // "SHUF"
    rng.shuffle(&mut examples);

    // 3+4. assign ordered indices implicitly (position after shuffle) and
    // write example i to file i % num_shards, preserving order within file.
    let n = examples.len();
    let shards = cfg.num_shards.max(1);
    let examples = std::sync::Arc::new(examples);
    let counts = parallel_map(shards, cfg.workers.max(1), |s| {
        let mut w =
            RecordWriter::create(CacheMeta::shard_file(dir, s)).expect("create shard");
        let mut i = s;
        while i < n {
            w.write(&serialize_example(&examples[i])).expect("write record");
            i += shards;
        }
        w.finish().expect("finish shard")
    });
    debug_assert_eq!(counts.iter().sum::<usize>(), n);

    let meta = CacheMeta {
        task: task.name.clone(),
        num_examples: n,
        num_shards: shards,
        seed: cfg.seed,
        split: split.to_string(),
        splits: None,
    };
    meta.save(dir)?;
    Ok(meta)
}

/// Atomically replace `out_dir` with `tmp_dir`.
fn commit(tmp_dir: &Path, out_dir: &Path) -> anyhow::Result<()> {
    if out_dir.exists() {
        std::fs::remove_dir_all(out_dir)?;
    }
    std::fs::rename(tmp_dir, out_dir)?;
    Ok(())
}

/// Run the single-split cache job (train split at the directory root —
/// the original layout): preprocess -> global shuffle -> index -> shard by
/// `index % num_shards`. Returns the metadata. Atomic: writes into a
/// `.tmp` directory then renames.
pub fn cache_task(
    task: &Task,
    out_dir: impl AsRef<Path>,
    cfg: &CacheConfig,
) -> anyhow::Result<CacheMeta> {
    let out_dir = out_dir.as_ref();
    let tmp_dir = out_dir.with_extension("tmp");
    if tmp_dir.exists() {
        std::fs::remove_dir_all(&tmp_dir)?;
    }
    let meta = write_split(task, "train", &tmp_dir, cfg)?;
    commit(&tmp_dir, out_dir)?;
    Ok(meta)
}

/// Cache *every* split the task declares, each under `splits/<name>/`
/// (per-split subdirectories), with a root metadata file listing them.
/// Returns the root metadata (`num_examples` = total over splits). Atomic
/// at the root: a reader never observes a partially cached split set.
pub fn cache_task_splits(
    task: &Task,
    out_dir: impl AsRef<Path>,
    cfg: &CacheConfig,
) -> anyhow::Result<CacheMeta> {
    let out_dir = out_dir.as_ref();
    let tmp_dir = out_dir.with_extension("tmp");
    if tmp_dir.exists() {
        std::fs::remove_dir_all(&tmp_dir)?;
    }
    std::fs::create_dir_all(&tmp_dir)?;
    let split_names = DatasetProvider::splits(task);
    let mut total = 0usize;
    for split in &split_names {
        let m = write_split(task, split, &CacheMeta::split_dir(&tmp_dir, split), cfg)?;
        total += m.num_examples;
    }
    let root = CacheMeta {
        task: task.name.clone(),
        num_examples: total,
        num_shards: cfg.num_shards.max(1),
        seed: cfg.seed,
        split: "train".to_string(),
        splits: Some(split_names),
    };
    root.save(&tmp_dir)?;
    commit(&tmp_dir, out_dir)?;
    Ok(root)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::seqio::preprocessors::Tokenize;
    use crate::seqio::records::RecordReader;
    use crate::seqio::source::SyntheticTextSource;
    use crate::seqio::task::Task;
    use crate::seqio::vocab::{ByteVocabulary, Vocabulary};
    use crate::seqio::deserialize_example;
    use std::sync::Arc;

    fn test_task(n: usize) -> Arc<Task> {
        let vocab: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(16));
        Task::builder("cache_test_task")
            .source(Arc::new(SyntheticTextSource::new(3, n)))
            .preprocessor(Arc::new(Tokenize::new(vocab.clone(), &[("text", "targets")])))
            .output_feature("targets", vocab, true)
            .build()
    }

    #[test]
    fn cache_roundtrip_and_layout() {
        let dir = std::env::temp_dir().join(format!("cache_{}", std::process::id()));
        let task = test_task(37);
        let cfg = CacheConfig { num_shards: 4, seed: 9, workers: 2 };
        let meta = cache_task(&task, &dir, &cfg).unwrap();
        assert_eq!(meta.num_examples, 37);
        assert_eq!(meta.num_shards, 4);
        let loaded = CacheMeta::load(&dir).unwrap();
        assert_eq!(loaded.num_examples, 37);

        // layout: shard s holds ceil((37 - s)/4) examples
        let mut total = 0;
        for s in 0..4 {
            let r = RecordReader::open(CacheMeta::shard_file(&dir, s)).unwrap();
            let expect = (37 + 4 - 1 - s) / 4;
            assert_eq!(r.len(), expect, "shard {s}");
            total += r.len();
        }
        assert_eq!(total, 37);

        // entries decode back into examples with expected features
        let mut r = RecordReader::open(CacheMeta::shard_file(&dir, 1)).unwrap();
        let ex = deserialize_example(&r.read_at(0).unwrap()).unwrap();
        assert!(ex.contains_key("targets"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn multi_split_cache_layout() {
        let dir = std::env::temp_dir().join(format!("cache_ms_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let vocab: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(16));
        let task = Task::builder("cache_ms_task")
            .source(Arc::new(SyntheticTextSource::new(3, 20)))
            .split_source("validation", Arc::new(SyntheticTextSource::new(99, 8)))
            .preprocessor(Arc::new(Tokenize::new(vocab.clone(), &[("text", "targets")])))
            .output_feature("targets", vocab, true)
            .build();
        let cfg = CacheConfig { num_shards: 4, seed: 2, workers: 2 };
        let root = cache_task_splits(&task, &dir, &cfg).unwrap();
        assert_eq!(
            root.splits.as_deref(),
            Some(["train".to_string(), "validation".to_string()].as_slice())
        );
        assert_eq!(root.num_examples, 28);
        // root meta loads and records the split list
        let loaded = CacheMeta::load(&dir).unwrap();
        assert_eq!(loaded.splits, root.splits);
        // each split subdirectory is itself a valid single-split cache
        for (split, n) in [("train", 20), ("validation", 8)] {
            let sub = CacheMeta::load(&CacheMeta::split_dir(&dir, split)).unwrap();
            assert_eq!(sub.num_examples, n, "{split}");
            assert_eq!(sub.split, split);
            assert!(sub.splits.is_none());
            assert!(CacheMeta::shard_file(&CacheMeta::split_dir(&dir, split), 0).exists());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cache_deterministic_given_seed() {
        let d1 = std::env::temp_dir().join(format!("cache_d1_{}", std::process::id()));
        let d2 = std::env::temp_dir().join(format!("cache_d2_{}", std::process::id()));
        let task = test_task(20);
        let cfg = CacheConfig { num_shards: 2, seed: 5, workers: 2 };
        cache_task(&task, &d1, &cfg).unwrap();
        cache_task(&task, &d2, &cfg).unwrap();
        for s in 0..2 {
            let a = std::fs::read(CacheMeta::shard_file(&d1, s)).unwrap();
            let b = std::fs::read(CacheMeta::shard_file(&d2, s)).unwrap();
            assert_eq!(a, b, "shard {s} differs");
        }
        // different seed -> different order
        let d3 = std::env::temp_dir().join(format!("cache_d3_{}", std::process::id()));
        let cfg3 = CacheConfig { seed: 6, ..cfg };
        cache_task(&task, &d3, &cfg3).unwrap();
        let a = std::fs::read(CacheMeta::shard_file(&d1, 0)).unwrap();
        let c = std::fs::read(CacheMeta::shard_file(&d3, 0)).unwrap();
        assert_ne!(a, c);
        for d in [&d1, &d2, &d3] {
            std::fs::remove_dir_all(d).ok();
        }
    }
}
