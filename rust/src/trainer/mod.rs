//! The t5x training loop (S7): a 2-D `data × model` mesh of simulated
//! hosts executing the `Partitioner`'s sharding plan — shard-resident
//! parameters, axis-subgroup collectives, ZeRO-style sharded optimizer
//! updates, metric logging, distributed checkpointing, and exact resume.
//!
//! ## Shard-resident execution (paper §2.2 at runtime)
//!
//! Every host keeps exactly one [`PartitionSpec`] block of each parameter
//! (and the matching optimizer-state block) resident — per-host memory is
//! ~`total/(data·model)` plus the replicated residue, for any mesh shape.
//! The step itself runs in one of two [`ExecMode`]s:
//!
//! **`ExecMode::Block`** (auto-selected when `mesh.model > 1` and the
//! artifacts carry a `block_exec` contract for that degree): the step feeds
//! resident model-axis blocks *straight into* per-segment HLOs and replays
//! the manifest's ordered collective schedule between them — an all-reduce
//! after each row-parallel matmul (the Megatron g-points) plus the four
//! vocab-parallel loss reductions. No full parameter is ever materialized:
//! per-host peak step memory drops from O(total params) to
//! O(block + activations), and model-axis traffic becomes activation-sized
//! reductions instead of parameter gathers. Gradients come out
//! block-shaped, so the slice-then-sync path collapses to sync-only.
//!
//! **`ExecMode::Gather`** (fallback + reference): one step, for host
//! `(d, m)`:
//!
//! 1. **infeed** — data-axis replica groups share batches: the row leader
//!    (`m == 0`) pulls the row's batch and broadcasts it over the
//!    model-axis subgroup (synthetic sources are recomputed locally, keyed
//!    by the data coordinate).
//! 2. **gather** — full parameters are reconstructed transiently with a
//!    data-axis then model-axis all-gather per sharded dimension (the
//!    unpartitioned HLO substrate needs full inputs; with `mesh.model == 1`
//!    the model-axis machinery is skipped entirely).
//! 3. **execute** — forward/backward on the device.
//! 4. **sync** — each host slices the gradient to its model-axis block
//!    (free: the values are already local) and syncs over the data-axis
//!    subgroup: reduce-scatter for data-sharded blocks, all-reduce for
//!    data-replicated ones. Parameters are *not* re-gathered after the
//!    update — they live sharded until the next step's gather.
//! 5. **update** — the optimizer updates only the resident block.
//!
//! Both modes produce the same resident-block gradients (Block is
//! bit-compatible on the loss at 2-rank rings and agrees to f32 reduction
//! order otherwise), so checkpoints written in one mode resume in the
//! other. `train/peak_param_floats` records the largest parameter or
//! gradient tensor a host materialized during the step — the measured
//! counterpart of the O(total) → O(block) claim.
//!
//! Strategy semantics: [`ParamStrategy::OneD`] shards parameters over the
//! model axis only (replicated over data — Megatron-style); with
//! `model == 1` this is the fully replicated baseline.
//! [`ParamStrategy::TwoD`] additionally shards over the data axis
//! (ZeRO-3/FSDP). Initialization is init-then-slice
//! ([`crate::model::shard_params`]) and 2-rank ring sums are
//! commutative, so a `d×m` TwoD run is bit-identical to the `d×1`
//! replicated baseline for elementwise optimizers when `d == 2` (asserted
//! by `tests/integration_sharded.rs`; wider data axes agree to summation
//! order).
//!
//! ## Overlapping communication with compute
//!
//! Every step executes an explicit `{Compute, Comm}` task schedule (see
//! [`schedule`]): the global batch is `k = microbatches`
//! gradient-accumulation microbatches, and each microbatch's data-axis
//! gradient reduce is dispatched to a per-host communication lane
//! ([`crate::collectives::CommLane`]). With `overlap` enabled the join is
//! deferred until the *next* microbatch's forward/backward has been
//! issued, so the ring runs under compute and only the join's blocked
//! time is exposed; with it disabled the same ops run in the same order
//! but are joined immediately. Gather-mode parameter materialization is
//! hoisted to once per step (parameters do not change between
//! microbatches), and block execution's resident-block data-axis gathers
//! are lane-routed so they serialize FIFO behind any in-flight reduce on
//! the same subgroup instead of corrupting the ring. Reduced gradients
//! accumulate strictly in microbatch order, so overlap on/off is
//! bit-identical (the [`schedule`] docs state the full numerics
//! contract), and a step either consumes all `k` microbatches or — on
//! stream exhaustion — applies nothing. `train/exposed_comm_ms` vs
//! `train/overlapped_comm_ms` quantify what actually got hidden.
//!
//! ## Distributed checkpoints
//!
//! Each owning host writes its disjoint block directly to the shared
//! `tstore` arrays (chunk-aligned sliced writes along axis 0, block grids
//! elsewhere) — no host ever gathers the full parameter set. Restore
//! range-reads each host's block regardless of the saving topology, so a
//! run saved on `4x2` resumes on `2x2` or `8x1`
//! (see [`crate::checkpoint`]).

pub mod eval;
pub mod infeed;
pub mod recipes;
pub mod schedule;
pub mod supervisor;

use std::cell::Cell;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::checkpoint::{block_coords, CheckpointManager};
use crate::collectives::{
    all_gather_axis, all_reduce_tensor_async, all_reduce_tensor_op, broadcast_batch,
    reduce_scatter_axis_async, run_ranks, CollectiveGroup, CommLane, MeshCollectives,
    PendingCollective, ReduceOp,
};
use crate::metrics::{CounterSet, MetricsLogger};
use crate::model::Params;
use crate::optim::{Optimizer, OptimizerKind, Schedule};
use crate::partitioning::{
    ExecMode, Mesh, MeshAxis, ParamStrategy, PartitionSpec, Partitioner, ShardPlan,
};
use crate::runtime::artifacts::ModelManifest;
use crate::runtime::{Artifacts, BlockExecDegree, DeviceHandle, Executable, HostTensor};
use crate::seqio::dataset::PipelineState;
use schedule::{plan_step, StepRunner, TaskKind};

/// Flat parameter layout: manifest order, contiguous f32. Retained as a
/// utility for tests/tools that want whole-model views; the trainer's
/// resident state is per-parameter blocks, not this flat vector.
#[derive(Debug, Clone)]
pub struct FlatLayout {
    /// (name, offset, len, shape) per parameter.
    pub entries: Vec<(String, usize, usize, Vec<usize>)>,
    pub total: usize,
}

impl FlatLayout {
    pub fn from_manifest(m: &ModelManifest) -> FlatLayout {
        let mut entries = Vec::with_capacity(m.params.len());
        let mut off = 0usize;
        for p in &m.params {
            let len = p.elements();
            entries.push((p.name.clone(), off, len, p.shape.clone()));
            off += len;
        }
        FlatLayout { entries, total: off }
    }

    pub fn flatten(&self, params: &Params) -> Vec<f32> {
        let mut out = vec![0.0f32; self.total];
        for (name, off, len, _) in &self.entries {
            out[*off..off + len].copy_from_slice(params[name].as_f32());
        }
        out
    }

    pub fn unflatten(&self, flat: &[f32]) -> Params {
        let mut out = Params::new();
        for (name, off, len, shape) in &self.entries {
            out.insert(
                name.clone(),
                HostTensor::f32(shape.clone(), flat[*off..off + len].to_vec()),
            );
        }
        out
    }

    /// Build executor inputs (manifest order) from the flat vector.
    pub fn tensors(&self, flat: &[f32]) -> Vec<HostTensor> {
        self.entries
            .iter()
            .map(|(_, off, len, shape)| {
                HostTensor::f32(shape.clone(), flat[*off..off + len].to_vec())
            })
            .collect()
    }
}

/// Where batches come from.
pub enum BatchSource {
    /// Deterministic random tokens (tests/benches), keyed by the *data
    /// row* — model-axis peers recompute the same batch locally.
    Synthetic { seed: u64 },
    /// A spawned seqio infeed: one prefetching stream per data row
    /// (spawn it with `num_hosts = mesh.data`); row leaders broadcast to
    /// their model-axis peers.
    Infeed(infeed::Infeed),
}

impl BatchSource {
    /// Per-row pipeline states as of the last consumed batch (None for
    /// stateless synthetic sources). Persisted with each checkpoint so the
    /// data stream resumes exactly where the params/optimizer do.
    fn pipeline_states(&self, num_rows: usize) -> Option<Vec<PipelineState>> {
        match self {
            BatchSource::Synthetic { .. } => None,
            BatchSource::Infeed(inf) => {
                Some((0..num_rows).map(|h| inf.pipeline_state(h)).collect())
            }
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainerConfig {
    pub model: String,
    /// The 2-D host mesh: `data` replica rows × `model` shards per row.
    pub mesh: Mesh,
    pub strategy: ParamStrategy,
    pub optimizer: OptimizerKind,
    pub schedule: Schedule,
    pub steps: u64,
    pub seed: u64,
    pub log_every: u64,
    pub checkpoint_every: Option<u64>,
    pub checkpoint_dir: Option<PathBuf>,
    /// Clip gradients to this global L2 norm (None = off). Computed on the
    /// *global* (post-all-reduce) gradient so all strategies agree.
    pub grad_clip_norm: Option<f64>,
    /// Decoupled (AdamW-style) weight decay per step (None = off).
    pub weight_decay: Option<f64>,
    /// How the step executes (see [`ExecMode`] and the module docs). The
    /// library default is `Gather` (the reference path); the CLI defaults
    /// to `Auto`, which upgrades to `Block` whenever the artifacts support
    /// the mesh's model degree.
    pub exec_mode: ExecMode,
    /// Write a Chrome trace-event JSON profile here after training
    /// (`--trace-out`, gin `trainer.trace_out`). None = tracing disarmed
    /// (the no-op tracer: no allocation on the hot path).
    pub trace_out: Option<PathBuf>,
    /// Only record spans for steps in `[N, M)` (`--profile-steps N..M`);
    /// None = trace every step. Ignored unless `trace_out` is set (or a
    /// tracer was attached via [`Trainer::with_tracer`]).
    pub profile_steps: Option<(u64, u64)>,
    /// Gradient-accumulation microbatches per step (`--microbatches`, gin
    /// `trainer.microbatches`). Each step consumes `k` manifest-shaped
    /// batches (microbatch `j` of step `t` is global batch `t·k + j`) and
    /// applies the in-order sum of their per-microbatch reduced gradients
    /// — numerically identical to a monolithic step over the same
    /// examples. Must be ≥ 1.
    pub microbatches: usize,
    /// Overlap each microbatch's data-axis gradient reduce with the next
    /// microbatch's forward/backward (`--overlap`, gin `trainer.overlap`).
    /// Same op sequence either way, so results are bit-identical; off =
    /// every reduce is joined immediately (fully exposed reference).
    pub overlap: bool,
    /// Infeed prefetch depth per data row (`--infeed-depth`, gin
    /// `trainer.infeed_depth`): how many batches the stream thread keeps
    /// decoded ahead of the consumer. 2 = double-buffering (batch t+1
    /// prepared while step t computes).
    pub infeed_depth: usize,
}

impl TrainerConfig {
    pub fn quick(model: &str, steps: u64) -> TrainerConfig {
        TrainerConfig {
            model: model.to_string(),
            mesh: Mesh::new(1, 1),
            strategy: ParamStrategy::OneD,
            optimizer: OptimizerKind::adam(),
            schedule: Schedule::RsqrtWithWarmup { peak: 3e-3, warmup: 20 },
            steps,
            seed: 0,
            log_every: 10,
            checkpoint_every: None,
            checkpoint_dir: None,
            grad_clip_norm: None,
            weight_decay: None,
            exec_mode: ExecMode::Gather,
            trace_out: None,
            profile_steps: None,
            microbatches: 1,
            overlap: false,
            infeed_depth: 2,
        }
    }

    pub fn num_hosts(&self) -> usize {
        self.mesh.num_hosts()
    }
}

/// Per-step metric record returned by [`Trainer::train`].
#[derive(Debug, Clone)]
pub struct StepMetrics {
    pub step: u64,
    pub loss: f64,
    pub accuracy: f64,
    pub lr: f64,
    pub step_seconds: f64,
}

pub struct TrainSummary {
    pub history: Vec<StepMetrics>,
    pub final_step: u64,
    /// Total bytes moved over all collectives (both axes + global group).
    pub comm_bytes: u64,
    /// Bytes moved over data-axis subgroups (gradient sync).
    pub data_axis_bytes: u64,
    /// Bytes moved over model-axis subgroups (parameter gathers, batch
    /// broadcast).
    pub model_axis_bytes: u64,
    /// Comm time host threads actually blocked on, µs summed over hosts
    /// (both collective phase timers, including async-join blocked time).
    pub exposed_comm_micros: u64,
    /// Comm-lane execution time hidden under compute, µs summed over
    /// hosts — the overlap win (0 for fully serial runs).
    pub overlapped_comm_micros: u64,
    pub wall_seconds: f64,
}

impl TrainSummary {
    pub fn final_loss(&self) -> f64 {
        self.history.last().map(|h| h.loss).unwrap_or(f64::NAN)
    }

    pub fn first_loss(&self) -> f64 {
        self.history.first().map(|h| h.loss).unwrap_or(f64::NAN)
    }
}

/// Per-host training state: one resident block per parameter (manifest
/// order, shapes from the [`ShardPlan`]) and the optimizer state for
/// exactly those blocks.
struct HostState {
    shards: Vec<HostTensor>,
    optimizer: Optimizer,
}

/// Accumulated wall time of one pipeline phase (all hosts summed),
/// microseconds. Drives the §Perf breakdown in `bench_train_step`.
#[derive(Default)]
pub struct PhaseTimer(AtomicU64);

impl PhaseTimer {
    fn add_since(&self, t0: Instant) {
        self.0.fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
    }

    /// Credit an externally measured duration (async-collective blocked
    /// time reported by [`crate::collectives::LaneStats`]).
    fn add_micros(&self, micros: u64) {
        self.0.fetch_add(micros, Ordering::Relaxed);
    }

    pub fn seconds(&self) -> f64 {
        self.0.load(Ordering::Relaxed) as f64 / 1e6
    }

    pub fn micros(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

/// Per-phase timing across the training loop. Collective time is split by
/// mesh axis so bench output distinguishes data-axis (gradient sync) from
/// model-axis (parameter gather / batch broadcast) communication.
#[derive(Default)]
pub struct TimingBreakdown {
    pub infeed: PhaseTimer,
    pub execute: PhaseTimer,
    pub collectives_data: PhaseTimer,
    pub collectives_model: PhaseTimer,
    pub optimizer: PhaseTimer,
}

impl TimingBreakdown {
    pub fn reset(&self) {
        self.infeed.reset();
        self.execute.reset();
        self.collectives_data.reset();
        self.collectives_model.reset();
        self.optimizer.reset();
    }

    /// (phase, seconds) rows, largest first.
    pub fn rows(&self) -> Vec<(&'static str, f64)> {
        let mut rows = vec![
            ("infeed", self.infeed.seconds()),
            ("execute", self.execute.seconds()),
            ("collectives/data", self.collectives_data.seconds()),
            ("collectives/model", self.collectives_model.seconds()),
            ("optimizer", self.optimizer.seconds()),
        ];
        rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        rows
    }

    /// Raw cumulative micros per phase (infeed, execute, coll-data,
    /// coll-model, optimizer) — deltas of consecutive snapshots give the
    /// per-step phase breakdown.
    pub fn snapshot_micros(&self) -> [u64; 5] {
        [
            self.infeed.micros(),
            self.execute.micros(),
            self.collectives_data.micros(),
            self.collectives_model.micros(),
            self.optimizer.micros(),
        ]
    }
}

/// Per-step phase-duration histograms, in milliseconds. Samples are
/// deltas of the shared [`TimingBreakdown`] observed by rank 0 at its
/// step boundaries — i.e. *summed over hosts* (on a 1×1 mesh they are
/// exact per-step durations). Cumulative across `train()` calls.
#[derive(Default, Clone)]
pub struct PhaseHistograms {
    pub infeed: crate::obs::Histogram,
    pub execute: crate::obs::Histogram,
    pub collectives_data: crate::obs::Histogram,
    pub collectives_model: crate::obs::Histogram,
    pub optimizer: crate::obs::Histogram,
    /// Rank-0 wall time per full step.
    pub step_ms: crate::obs::Histogram,
}

impl PhaseHistograms {
    fn record_deltas_ms(&self, d: &[f64; 5]) {
        self.infeed.record_ms(d[0]);
        self.execute.record_ms(d[1]);
        self.collectives_data.record_ms(d[2]);
        self.collectives_model.record_ms(d[3]);
        self.optimizer.record_ms(d[4]);
    }

    /// Emit p50/p95/p99/mean/count for every phase at `step` (the
    /// end-of-run `train/phase_*_ms` percentile block).
    pub fn log_to(&self, logger: &MetricsLogger, step: u64) {
        self.infeed.log_to(logger, step, "train/phase_infeed_ms");
        self.execute.log_to(logger, step, "train/phase_execute_ms");
        self.collectives_data.log_to(logger, step, "train/phase_coll_data_ms");
        self.collectives_model.log_to(logger, step, "train/phase_coll_model_ms");
        self.optimizer.log_to(logger, step, "train/phase_optimizer_ms");
        self.step_ms.log_to(logger, step, "train/step_ms");
    }
}

fn clip_scale_from_norm(clip: Option<f64>, norm: f64) -> f32 {
    match clip {
        Some(c) if norm > c && norm > 0.0 => (c / norm) as f32,
        _ => 1.0,
    }
}

fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// The compiled step: one monolithic HLO (Gather) or the block-segment
/// programs plus the manifest contract they replay (Block).
enum StepProgram {
    Gather(Executable),
    Block(BlockProgram),
}

/// Block-execution state resolved at [`Trainer::new`]: the per-degree
/// contract from the manifest, one compiled executable per segment, and a
/// name → plan-entry index for O(1) block lookups in the hot loop.
struct BlockProgram {
    spec: BlockExecDegree,
    segments: BTreeMap<String, Executable>,
    param_index: BTreeMap<String, usize>,
}

impl BlockProgram {
    fn index(&self, name: &str) -> anyhow::Result<usize> {
        self.param_index
            .get(name)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("block step references unknown param '{name}'"))
    }
}

/// Map a manifest collective-op string to the ring reduction it names.
fn parse_reduce_op(op: &str) -> anyhow::Result<ReduceOp> {
    match op {
        "all_reduce_sum" => Ok(ReduceOp::Sum),
        "all_reduce_max" => Ok(ReduceOp::Max),
        "all_reduce_min" => Ok(ReduceOp::Min),
        other => anyhow::bail!("unknown block collective op '{other}'"),
    }
}

pub struct Trainer {
    pub manifest: ModelManifest,
    pub layout: FlatLayout,
    pub config: TrainerConfig,
    /// The executed sharding: per-parameter specs + block shapes.
    pub plan: ShardPlan,
    pub partitioner: Partitioner,
    /// The resolved execution mode (`Auto` never survives construction).
    pub exec_mode: ExecMode,
    program: StepProgram,
    colls: Arc<MeshCollectives>,
    /// Largest parameter/gradient tensor (elements) any host materialized
    /// inside a train step — the measured O(total) vs O(block) claim.
    peak_param_floats: AtomicU64,
    /// Comm-lane execution micros the host threads did not block on
    /// (hidden under compute; summed over hosts). Exposed comm is the
    /// collective phase timers. Reset per `train()`.
    overlapped_comm_micros: AtomicU64,
    hosts: Vec<Mutex<HostState>>,
    pub start_step: u64,
    /// Per-row data pipeline states recovered by [`Trainer::restore_latest`]
    /// (None when the checkpoint predates pipeline checkpointing, the run
    /// used a synthetic source, or the data-row count changed — the coarse
    /// `start_step` positioning then applies). Pass to
    /// [`infeed::Infeed::spawn_resumable`] to resume the exact stream.
    pub restored_pipeline: Option<Vec<PipelineState>>,
    pub logger: Arc<MetricsLogger>,
    /// Per-phase wall-time accounting (summed over hosts); reset per train().
    pub timing: TimingBreakdown,
    /// Cumulative training counters, including per-axis collective traffic
    /// (`train/data_axis_bytes`, `train/model_axis_bytes`, `.../ops`).
    pub counters: CounterSet,
    /// Span tracer: armed iff `config.trace_out` is set or a tracer was
    /// attached via [`Trainer::with_tracer`]; the disarmed default is a
    /// no-op (see the lib.rs Observability overhead contract).
    pub tracer: Arc<crate::obs::Tracer>,
    /// Per-step phase-duration histograms (`train/phase_*_ms` p50/p99).
    pub phase_hist: PhaseHistograms,
}

impl Trainer {
    pub fn new(
        arts: &Artifacts,
        device: &DeviceHandle,
        config: TrainerConfig,
    ) -> anyhow::Result<Trainer> {
        anyhow::ensure!(
            config.microbatches >= 1,
            "trainer.microbatches must be >= 1 (got 0)"
        );
        let manifest = arts.model(&config.model)?.clone();
        let layout = FlatLayout::from_manifest(&manifest);
        let partitioner = Partitioner::new(config.mesh, config.strategy);
        let plan = ShardPlan::new(&partitioner, &manifest.params);
        let colls = MeshCollectives::new(config.mesh);

        // ---- resolve the execution mode against the artifact contract ----
        let degree = config.mesh.model;
        let exec_mode = match config.exec_mode {
            ExecMode::Gather => ExecMode::Gather,
            ExecMode::Auto => {
                if degree > 1 && manifest.supports_block_exec(degree) {
                    ExecMode::Block
                } else {
                    ExecMode::Gather
                }
            }
            ExecMode::Block => {
                anyhow::ensure!(
                    manifest.supports_block_exec(degree),
                    "exec mode 'block' was forced, but the artifacts carry no block_exec \
                     contract for model '{}' at model-axis degree {degree}; re-export \
                     artifacts (make artifacts) or run with --exec-mode gather",
                    config.model
                );
                ExecMode::Block
            }
        };
        let program = match exec_mode {
            ExecMode::Block => {
                let spec = manifest
                    .block_exec(degree)
                    .expect("supports_block_exec checked above")
                    .clone();
                let mut segments = BTreeMap::new();
                for (seg, hlo) in &spec.segments {
                    let (exe, _) = device.compile(hlo)?;
                    segments.insert(seg.clone(), exe);
                }
                let mut param_index = BTreeMap::new();
                for (i, e) in plan.entries.iter().enumerate() {
                    param_index.insert(e.name.clone(), i);
                    // cross-validate: the manifest's block shape must equal
                    // the plan's model-axis block (the data-gathered shard)
                    let b = spec.param(&e.name).ok_or_else(|| {
                        anyhow::anyhow!("block_exec contract misses param '{}'", e.name)
                    })?;
                    let mut expect = e.shape.clone();
                    if let Some((dim, n_m)) = e.spec.dim_for(MeshAxis::Model) {
                        expect[dim] /= n_m;
                    }
                    anyhow::ensure!(
                        b.block_shape == expect,
                        "block_exec contract for '{}' declares block {:?}, \
                         but the partitioner produces {:?}",
                        e.name,
                        b.block_shape,
                        expect
                    );
                }
                StepProgram::Block(BlockProgram { spec, segments, param_index })
            }
            _ => {
                let (exe, _) = device.compile(&manifest.entrypoint("train_step")?.hlo)?;
                StepProgram::Gather(exe)
            }
        };

        // Init-then-slice: generate the full set once with the exact
        // replicated-baseline RNG stream, keep only the per-host blocks
        // (the full set exists only during construction).
        let init = crate::model::init_params(&manifest, config.seed);
        let hosts = (0..config.mesh.num_hosts())
            .map(|h| {
                Mutex::new(HostState {
                    shards: crate::model::shard_params(&init, &plan, h),
                    optimizer: Self::build_optimizer(&config, &plan),
                })
            })
            .collect();
        let tracer = if config.trace_out.is_some() {
            let t = crate::obs::Tracer::new();
            colls.set_tracer(&t);
            t
        } else {
            crate::obs::Tracer::off()
        };
        Ok(Trainer {
            manifest,
            layout,
            config,
            plan,
            partitioner,
            exec_mode,
            program,
            colls,
            peak_param_floats: AtomicU64::new(0),
            overlapped_comm_micros: AtomicU64::new(0),
            hosts,
            start_step: 0,
            restored_pipeline: None,
            logger: Arc::new(MetricsLogger::new()),
            timing: TimingBreakdown::default(),
            counters: CounterSet::new(),
            tracer,
            phase_hist: PhaseHistograms::default(),
        })
    }

    /// Largest parameter/gradient tensor (elements) any host materialized
    /// during training so far. In `Gather` mode this is the largest *full*
    /// parameter; in `Block` mode it stays at the largest model-axis block
    /// — the per-host peak-memory headline of block execution.
    pub fn peak_param_floats(&self) -> usize {
        self.peak_param_floats.load(Ordering::Relaxed) as usize
    }

    fn note_param_peak(&self, elements: usize) {
        self.peak_param_floats.fetch_max(elements as u64, Ordering::Relaxed);
    }

    pub fn with_logger(mut self, logger: MetricsLogger) -> Self {
        self.logger = Arc::new(logger);
        self
    }

    /// Adjust how many steps the next [`Self::train`] call runs. The
    /// supervisor uses this to re-target a restarted attempt at the
    /// *original* end step (`restored_step + steps == target_end`), so a
    /// supervised run never over- or under-trains.
    pub fn set_steps(&mut self, steps: u64) {
        self.config.steps = steps;
    }

    /// The configured checkpoint directory, if any.
    pub fn checkpoint_dir(&self) -> Option<&PathBuf> {
        self.config.checkpoint_dir.as_ref()
    }

    /// Attach an externally owned tracer (benches/tests that want spans
    /// without a `trace_out` file); also wires it into the collective
    /// groups.
    pub fn with_tracer(mut self, tracer: Arc<crate::obs::Tracer>) -> Self {
        self.colls.set_tracer(&tracer);
        self.tracer = tracer;
        self
    }

    /// Register one optimizer entry per parameter *block*. Factoring
    /// (Adafactor) applies to the block's matrix shape — factored stats
    /// are therefore functions of the saving topology and checkpoint as
    /// topology-local arrays.
    fn build_optimizer(config: &TrainerConfig, plan: &ShardPlan) -> Optimizer {
        let mut opt = Optimizer::new(config.optimizer, config.schedule);
        for e in &plan.entries {
            let mat = if e.shard_shape.len() >= 2 {
                Some((e.shard_shape[0], e.shard_shape[1..].iter().product()))
            } else {
                None
            };
            opt.register(&e.name, e.shard_elems(), mat);
        }
        opt
    }

    /// Total optimizer-state floats currently held per host (memory claim).
    pub fn optimizer_state_floats(&self, host: usize) -> usize {
        self.hosts[host].lock().unwrap().optimizer.state_floats()
    }

    /// Parameter floats resident on `host` — the per-host memory claim of
    /// §2.2 (transient gather buffers excluded; see module docs).
    pub fn resident_param_floats(&self, host: usize) -> usize {
        self.hosts[host]
            .lock()
            .unwrap()
            .shards
            .iter()
            .map(|t| t.elements())
            .sum()
    }

    /// Diagnostic: one optimizer slot vector of `host`'s resident block
    /// (tests use it to verify checkpoint resharding of optimizer state).
    pub fn optimizer_slot(&self, host: usize, name: &str, slot: &str) -> Option<Vec<f32>> {
        self.hosts[host]
            .lock()
            .unwrap()
            .optimizer
            .state_vectors(name)
            .into_iter()
            .find(|(s, _)| s == slot)
            .map(|(_, v)| v)
    }

    /// Current parameters, gathered on demand from every host's resident
    /// blocks (there is no free full copy anywhere).
    pub fn params(&self) -> Params {
        // one lock per host; the per-shard clones are O(1) Arc bumps
        let per_host: Vec<Vec<HostTensor>> = self
            .hosts
            .iter()
            .map(|h| h.lock().unwrap().shards.clone())
            .collect();
        let mut out = Params::new();
        for (i, e) in self.plan.entries.iter().enumerate() {
            let shards: Vec<HostTensor> =
                per_host.iter().map(|s| s[i].clone()).collect();
            out.insert(e.name.clone(), self.partitioner.unshard(&shards, &e.spec));
        }
        out
    }

    /// Run the training loop over `source`, returning per-step metrics.
    pub fn train(&self, source: &BatchSource) -> anyhow::Result<TrainSummary> {
        let n = self.config.mesh.num_hosts();
        let history = Mutex::new(Vec::<StepMetrics>::new());
        let stop_step = AtomicU64::new(u64::MAX);
        let t0 = Instant::now();
        self.colls.reset_stats();
        self.timing.reset();
        self.overlapped_comm_micros.store(0, Ordering::Relaxed);
        if self.tracer.is_armed() {
            // Default-enabled unless a profile window narrows it per step.
            self.tracer.set_enabled(self.config.profile_steps.is_none());
            if let BatchSource::Infeed(inf) = source {
                inf.attach_tracer(self.tracer.clone());
            }
        }

        let errors: Vec<Option<String>> = run_ranks(n, |rank| {
            // A failed or panicked host can no longer serve its ring
            // position: poison the shared abort flag so peers blocked in a
            // collective (or on the comm lane) fail loudly instead of
            // waiting forever on a vanished neighbor, and collect every
            // host's message so the root cause is reported, not just the
            // induced aborts.
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                self.host_loop(rank, source, &history, &stop_step)
            }));
            match result {
                Ok(Ok(())) => None,
                Ok(Err(e)) => {
                    self.colls.abort_handle().store(true, Ordering::SeqCst);
                    Some(format!("host {rank}: {e}"))
                }
                Err(p) => {
                    self.colls.abort_handle().store(true, Ordering::SeqCst);
                    Some(format!("host {rank} panicked: {}", panic_message(p)))
                }
            }
        });
        let errors: Vec<String> = errors.into_iter().flatten().collect();
        if !errors.is_empty() {
            anyhow::bail!("{}", errors.join("; "));
        }
        // A dead producer drains like exhaustion (so no rank strands a
        // peer mid-collective), then surfaces here as a hard error.
        if let BatchSource::Infeed(inf) = source {
            anyhow::ensure!(
                !inf.failed(),
                "infeed producer thread panicked (e.g. get_dataset stream validation \
                 failed — see stderr); refusing to report the dead stream as a \
                 completed run"
            );
        }
        let mut history = history.into_inner().unwrap();
        history.sort_by_key(|h| h.step);
        let final_step = history.last().map(|h| h.step + 1).unwrap_or(self.start_step);
        let data_axis_bytes = self.colls.axis_bytes(MeshAxis::Data);
        let model_axis_bytes = self.colls.axis_bytes(MeshAxis::Model);
        // Exposed = host-thread blocked time on comm (sync ops + async-join
        // waits, both phase-timed); overlapped = lane exec time hidden
        // under compute. Both reset at the top of train().
        let exposed_comm_micros = self.timing.collectives_data.micros()
            + self.timing.collectives_model.micros();
        let overlapped_comm_micros = self.overlapped_comm_micros.load(Ordering::Relaxed);
        self.counters.add("train/data_axis_bytes", data_axis_bytes);
        self.counters.add("train/model_axis_bytes", model_axis_bytes);
        self.counters.add("train/data_axis_ops", self.colls.axis_ops(MeshAxis::Data));
        self.counters.add("train/model_axis_ops", self.colls.axis_ops(MeshAxis::Model));
        self.counters.add("train/exposed_comm_ms", exposed_comm_micros / 1000);
        self.counters.add("train/overlapped_comm_ms", overlapped_comm_micros / 1000);
        if let BatchSource::Infeed(inf) = source {
            self.counters.set_max("train/infeed_retries", inf.retries());
        }
        self.counters
            .set_max("train/peak_param_floats", self.peak_param_floats.load(Ordering::Relaxed));
        self.counters.log_to(&self.logger, final_step);
        self.phase_hist.log_to(&self.logger, final_step);
        self.logger.flush();
        if self.tracer.is_armed() {
            // Trace-summary reads the starvation verdict off the trace
            // itself, so mirror the counter there before export.
            self.tracer.set_enabled(true);
            self.tracer.counter(
                "train/infeed_starved_steps",
                self.counters.get("train/infeed_starved_steps") as f64,
            );
            if let Some(path) = &self.config.trace_out {
                self.tracer.export_or_warn(path);
            }
        }
        Ok(TrainSummary {
            history,
            final_step,
            comm_bytes: self.colls.bytes_sent(),
            data_axis_bytes,
            model_axis_bytes,
            exposed_comm_micros,
            overlapped_comm_micros,
            wall_seconds: t0.elapsed().as_secs_f64(),
        })
    }

    fn host_loop(
        &self,
        rank: usize,
        source: &BatchSource,
        history: &Mutex<Vec<StepMetrics>>,
        stop_step: &AtomicU64,
    ) -> anyhow::Result<()> {
        let m = &self.manifest;
        let mesh = self.config.mesh;
        let (d_coord, m_coord) = mesh.coords(rank);
        let (dg, dr) = self.colls.data_group(rank);
        let (mg, mr) = self.colls.model_group(rank);
        let template: Vec<(Vec<usize>, bool)> = m
            .batch_features
            .iter()
            .map(|f| (f.shape.clone(), f.is_int))
            .collect();
        if self.tracer.is_armed() {
            self.tracer.name_track(&format!("host{rank} (d{d_coord},m{m_coord})"));
        }
        // ---- the step schedule + its executor: one comm lane per host,
        // alive across steps (drained at every step boundary) ----
        let k = self.config.microbatches;
        let plan_tasks = plan_step(k, self.config.overlap);
        let (dg_arc, _) = self.colls.data_group_arc(rank);
        let runner = StepRunner::new(
            CommLane::new(self.colls.abort_handle()),
            &self.timing.collectives_data,
            &self.overlapped_comm_micros,
        );
        if self.tracer.is_armed() {
            runner.lane().set_tracer(self.tracer.clone());
            let t = self.tracer.clone();
            let label = format!("host{rank} comm-lane");
            runner.dispatch("lane/name_track", move || t.name_track(label)).wait();
        }
        let end = self.start_step + self.config.steps;
        for step in self.start_step..end {
            if step >= stop_step.load(Ordering::Acquire) {
                break;
            }
            if let Some((a, b)) = self.config.profile_steps {
                if self.tracer.is_armed() {
                    self.tracer.set_enabled(step >= a && step < b);
                }
            }
            let t_step = Instant::now();
            let _step_span = self.tracer.span("train/step").arg("step", step);
            // S10 injection point: host_panic / slow_host keyed (host, step).
            crate::faults::maybe_inject("trainer/step", rank, step);
            let phase0 =
                if rank == 0 { Some(self.timing.snapshot_micros()) } else { None };
            // ---- per-step prepared state: resident shards (O(1) Arc
            // bumps) and, in gather mode, the full parameters materialized
            // ONCE — they do not change across microbatches, so the
            // microbatch loop is pure infeed + execute and the comm lane
            // has a real window to hide the grad reduces in. ----
            let shards: Vec<HostTensor> = {
                let host = self.hosts[rank].lock().unwrap();
                host.shards.clone() // O(1) Arc bumps
            };
            let full_params: Option<Vec<HostTensor>> = match &self.program {
                StepProgram::Gather(_) => Some(self.gather_params(rank, &shards)),
                StepProgram::Block(_) => None,
            };

            // ---- execute the step plan over k microbatches ----
            let mut acc_loss = 0f32;
            let mut acc_weight = 0f32;
            let mut acc_correct = 0f32;
            let mut acc_grads: Vec<Option<HostTensor>> =
                vec![None; self.plan.entries.len()];
            let mut inflight: Vec<Option<Vec<PendingCollective<HostTensor>>>> =
                (0..k).map(|_| None).collect();
            let mut batch_slot: Option<Vec<HostTensor>> = None;
            let mut grads_slot: Option<Vec<HostTensor>> = None;
            let mut exhausted = false;
            for task in &plan_tasks {
                match task.kind {
                    TaskKind::Infeed => {
                        let index = step * k as u64 + task.microbatch as u64;
                        match self.fetch_batch(source, index, d_coord, m_coord, mg, mr, &template)
                        {
                            Some(b) => batch_slot = Some(b),
                            None => {
                                exhausted = true;
                                break;
                            }
                        }
                    }
                    TaskKind::ForwardBackward => {
                        let batch =
                            batch_slot.take().expect("plan runs Infeed before ForwardBackward");
                        let (ls, ws, cs, grads) = match &self.program {
                            StepProgram::Gather(exe) => self.gather_compute(
                                exe,
                                rank,
                                full_params.as_ref().expect("materialized for gather mode"),
                                batch,
                            )?,
                            StepProgram::Block(bp) => {
                                self.block_step(bp, rank, &shards, batch, &runner)?
                            }
                        };
                        anyhow::ensure!(
                            ls.is_finite(),
                            "non-finite loss at step {step} (microbatch {})",
                            task.microbatch
                        );
                        acc_loss += ls;
                        acc_weight += ws;
                        acc_correct += cs;
                        grads_slot = Some(grads);
                    }
                    TaskKind::DispatchGradReduce => {
                        let grads = grads_slot
                            .take()
                            .expect("plan runs ForwardBackward before DispatchGradReduce");
                        let mut handles = Vec::with_capacity(grads.len());
                        for (e, g) in self.plan.entries.iter().zip(grads) {
                            handles.push(match e.spec.dim_for(MeshAxis::Data) {
                                Some((dim, _)) => reduce_scatter_axis_async(
                                    &dg_arc,
                                    runner.lane(),
                                    dr,
                                    g,
                                    dim,
                                ),
                                None => {
                                    all_reduce_tensor_async(&dg_arc, runner.lane(), dr, g)
                                }
                            });
                        }
                        inflight[task.microbatch] = Some(handles);
                    }
                    TaskKind::WaitGradReduce => {
                        let handles = inflight[task.microbatch]
                            .take()
                            .expect("plan dispatches before waiting");
                        let _sp = self
                            .tracer
                            .span("train/settle_grads")
                            .arg("microbatch", task.microbatch);
                        // strict microbatch-order accumulation: the f32
                        // summation tree is independent of overlap mode
                        for (slot, p) in acc_grads.iter_mut().zip(handles) {
                            let g = runner.settle(p);
                            *slot = Some(match slot.take() {
                                Some(prev) => prev.add(&g),
                                None => g,
                            });
                        }
                    }
                    TaskKind::Finalize => {}
                }
            }
            if exhausted {
                // Data exhausted mid-step (all rows cut at the same
                // microbatch — shards are balanced and the row broadcast
                // propagates the flag): drain any in-flight reduces so the
                // lanes quiesce symmetrically, discard the partial
                // accumulation, and stop. A step either consumes all k
                // microbatches or applies nothing.
                for handles in inflight.iter_mut().filter_map(|h| h.take()) {
                    for p in handles {
                        let _ = runner.settle(p);
                    }
                }
                stop_step.fetch_min(step, Ordering::AcqRel);
                break;
            }

            // ---- finalize: one scalar sync over the full effective
            // batch, then clip + update on the accumulated gradient —
            // identical to the monolithic step's epilogue. The lane is
            // drained here, so host-thread collectives are safe again. ----
            // S10 injection point: comm_stall delays this host *before* it
            // enters the sync collective, so its ring peers are the ones
            // that hit the receive deadline (naming the stalled point).
            crate::faults::maybe_inject("trainer/grad_sync", rank, step);
            let grad_sync_span = self.tracer.span("train/grad_sync");
            let t_sc = Instant::now();
            let scalars = dg.all_reduce(dr, vec![acc_loss, acc_weight, acc_correct]);
            self.timing.collectives_data.add_since(t_sc);
            let w_total = scalars[1].max(1e-9);
            let grad_shards: Vec<HostTensor> = acc_grads
                .into_iter()
                .map(|g| g.expect("every microbatch accumulated into every grad slot"))
                .collect();

            // ---- global-norm clip scale (norm over owned blocks only, so
            // replicas are not double counted) ----
            let clip = self.config.grad_clip_norm;
            let scale = if clip.is_some() {
                let local_sq: f64 = self
                    .plan
                    .entries
                    .iter()
                    .zip(&grad_shards)
                    .filter(|(e, _)| e.spec.owns(&mesh, rank))
                    .flat_map(|(_, g)| g.as_f32())
                    .map(|&x| {
                        let v = (x / w_total) as f64;
                        v * v
                    })
                    .sum();
                let t0 = Instant::now();
                let total_sq =
                    self.colls.global().all_reduce(rank, vec![local_sq as f32])[0] as f64;
                self.timing.collectives_data.add_since(t0);
                clip_scale_from_norm(clip, total_sq.sqrt()) / w_total
            } else {
                1.0 / w_total
            };
            drop(grad_sync_span);

            // ---- optimizer update on resident blocks only ----
            let opt_span = self.tracer.span("train/optimizer");
            let t_opt = Instant::now();
            let decay = self.config.weight_decay.map(|d| d as f32);
            let lr_now = self.config.schedule.lr(step) as f32;
            {
                let mut host = self.hosts[rank].lock().unwrap();
                let HostState { shards, optimizer } = &mut *host;
                for ((e, shard), g) in
                    self.plan.entries.iter().zip(shards.iter_mut()).zip(&grad_shards)
                {
                    let gv: Vec<f32> = g.as_f32().iter().map(|&x| x * scale).collect();
                    let pv = shard.as_f32_mut();
                    if let Some(dcy) = decay {
                        for p in pv.iter_mut() {
                            *p -= lr_now * dcy * *p;
                        }
                    }
                    optimizer.update(&e.name, step, pv, &gv);
                }
            }
            self.timing.optimizer.add_since(t_opt);
            drop(opt_span);

            // ---- metrics (host (0,0)) ----
            if rank == 0 {
                let loss = (scalars[0] / scalars[1]) as f64;
                let acc = (scalars[2] / scalars[1]) as f64;
                let lr = self.config.schedule.lr(step);
                let rec = StepMetrics {
                    step,
                    loss,
                    accuracy: acc,
                    lr,
                    step_seconds: t_step.elapsed().as_secs_f64(),
                };
                // Per-step phase deltas off the shared timing breakdown
                // (summed over all hosts this step; exact on a 1x1 mesh).
                if let Some(p0) = phase0 {
                    let p1 = self.timing.snapshot_micros();
                    let mut d = [0f64; 5];
                    for i in 0..5 {
                        d[i] = p1[i].saturating_sub(p0[i]) as f64 / 1000.0;
                    }
                    self.phase_hist.record_deltas_ms(&d);
                    self.phase_hist.step_ms.record_ms(rec.step_seconds * 1e3);
                }
                if step % self.config.log_every == 0 || step + 1 == end {
                    // k microbatches = k manifest-shaped batches per step
                    let tokens =
                        (m.tokens_per_step() * mesh.data * k) as f64 / rec.step_seconds;
                    let mut vals = vec![
                        ("loss", loss),
                        ("accuracy", acc),
                        ("lr", lr),
                        ("tokens_per_sec", tokens),
                    ];
                    let depth = match source {
                        BatchSource::Infeed(inf) => Some(inf.queue_depth(d_coord)),
                        _ => None,
                    };
                    if let Some(depth) = depth {
                        vals.push(("train/infeed_queue_depth", depth as f64));
                        self.tracer.counter("train/infeed_queue_depth", depth as f64);
                    }
                    self.logger.log(step, &vals);
                }
                history.lock().unwrap().push(rec);
            }

            // ---- checkpoint hook ----
            if let (Some(every), Some(dir)) =
                (self.config.checkpoint_every, self.config.checkpoint_dir.as_ref())
            {
                if (step + 1) % every == 0 || step + 1 == end {
                    self.checkpoint_barrier(rank, step + 1, dir, source)?;
                }
            }
        }
        Ok(())
    }

    /// One microbatch from the data row's source: leaders (`m == 0`) pull
    /// — or synthesize, keyed by the global batch index `step·k + j` — and
    /// model-axis peers receive the row broadcast. `None` = exhausted.
    /// The pull/wait counts as infeed; the broadcast as model-axis
    /// collective time (no overlap between phases).
    #[allow(clippy::too_many_arguments)]
    fn fetch_batch(
        &self,
        source: &BatchSource,
        batch_index: u64,
        d_coord: usize,
        m_coord: usize,
        mg: &CollectiveGroup,
        mr: usize,
        template: &[(Vec<usize>, bool)],
    ) -> Option<Vec<HostTensor>> {
        let mesh = self.config.mesh;
        let t_inf = Instant::now();
        match source {
            BatchSource::Synthetic { seed } => {
                let b = Some(infeed::synthetic_batch(
                    &self.manifest,
                    *seed,
                    d_coord,
                    batch_index,
                ));
                self.timing.infeed.add_since(t_inf);
                b
            }
            BatchSource::Infeed(inf) => {
                let leader = if m_coord == 0 {
                    let _sp = self.tracer.span("train/infeed");
                    inf.next_counted(d_coord, &self.counters)
                } else {
                    None
                };
                self.timing.infeed.add_since(t_inf);
                if mesh.model == 1 {
                    leader
                } else {
                    let t_b = Instant::now();
                    let _sp = self.tracer.span("train/broadcast_batch");
                    let out = broadcast_batch(mg, mr, leader, template);
                    self.timing.collectives_model.add_since(t_b);
                    out
                }
            }
        }
    }

    /// `ExecMode::Gather`, phase 1: transiently reconstruct the full
    /// parameter set (data-axis then model-axis all-gather per sharded
    /// dim). Runs once per step — parameters do not change between
    /// microbatches, so one materialization serves all k executions and
    /// the gathers never land inside the overlap window. With
    /// `mesh.model == 1` the model-axis machinery is skipped entirely (no
    /// degenerate 1-rank calls, no timing probes).
    fn gather_params(&self, rank: usize, shards: &[HostTensor]) -> Vec<HostTensor> {
        let mesh = self.config.mesh;
        let (dg, dr) = self.colls.data_group(rank);
        let (mg, mr) = self.colls.model_group(rank);
        let mut full = Vec::with_capacity(self.plan.entries.len());
        for (e, shard) in self.plan.entries.iter().zip(shards) {
            let mut t = shard.clone();
            if let Some((dim, _)) = e.spec.dim_for(MeshAxis::Data) {
                let t0 = Instant::now();
                t = all_gather_axis(dg, dr, &t, dim);
                self.timing.collectives_data.add_since(t0);
            }
            if mesh.model > 1 {
                if let Some((dim, _)) = e.spec.dim_for(MeshAxis::Model) {
                    let t0 = Instant::now();
                    t = all_gather_axis(mg, mr, &t, dim);
                    self.timing.collectives_model.add_since(t0);
                }
            }
            self.note_param_peak(t.elements());
            full.push(t);
        }
        full
    }

    /// `ExecMode::Gather`, phase 2: run the monolithic `train_step` HLO on
    /// the pre-materialized full parameters and one microbatch, slicing
    /// each gradient back to this host's model-axis block.
    fn gather_compute(
        &self,
        exe: &Executable,
        rank: usize,
        full_params: &[HostTensor],
        batch: Vec<HostTensor>,
    ) -> anyhow::Result<(f32, f32, f32, Vec<HostTensor>)> {
        let mesh = self.config.mesh;
        let (_, m_coord) = mesh.coords(rank);
        let mut inputs = Vec::with_capacity(full_params.len() + batch.len());
        inputs.extend(full_params.iter().cloned()); // O(1) Arc bumps
        inputs.extend(batch);
        let _exec_span = self.tracer.span("train/execute");
        let t_exec = Instant::now();
        let outs = exe.run(inputs)?;
        self.timing.execute.add_since(t_exec);
        let (loss_sum, weight_sum, correct_sum) =
            (outs[0].first_f32(), outs[1].first_f32(), outs[2].first_f32());
        let mut grads = Vec::with_capacity(self.plan.entries.len());
        for (i, e) in self.plan.entries.iter().enumerate() {
            let mut g = outs[3 + i].clone();
            self.note_param_peak(g.elements());
            if mesh.model > 1 {
                if let Some((dim, n_m)) = e.spec.dim_for(MeshAxis::Model) {
                    let size = e.shape[dim] / n_m;
                    g = g.slice_axis(dim, m_coord * size, size);
                }
            }
            grads.push(g);
        }
        Ok((loss_sum, weight_sum, correct_sum, grads))
    }

    /// `ExecMode::Block` step: run the 12 block segments on resident
    /// model-axis blocks, replaying the manifest's ordered collective
    /// schedule at every Megatron f/g point. Mirrors
    /// `python/compile/model.py::block_reference_step` exactly — that
    /// simulation is the contract's source of truth, asserted against the
    /// monolithic step at export time. No full parameter (or full-vocab
    /// logit gather) is ever materialized.
    fn block_step(
        &self,
        bp: &BlockProgram,
        rank: usize,
        shards: &[HostTensor],
        batch: Vec<HostTensor>,
        runner: &StepRunner<'_>,
    ) -> anyhow::Result<(f32, f32, f32, Vec<HostTensor>)> {
        let mesh = self.config.mesh;
        let (_, m_coord) = mesh.coords(rank);
        let (dg_arc, dr) = self.colls.data_group_arc(rank);
        let (mg, mr) = self.colls.model_group(rank);
        let nl = self.manifest.cfg_usize("num_layers");
        let feature = |name: &str| -> anyhow::Result<HostTensor> {
            self.manifest
                .batch_features
                .iter()
                .position(|f| f.name == name)
                .map(|i| batch[i].clone())
                .ok_or_else(|| anyhow::anyhow!("batch misses feature '{name}'"))
        };
        let tokens = feature("decoder_input_tokens")?;
        let targets = feature("decoder_target_tokens")?;
        let weights = feature("decoder_loss_weights")?;
        let shard_t = HostTensor::i32(vec![], vec![m_coord as i32]);
        let layer = |i: usize, s: &str| format!("decoder.layers_{i}.{s}");

        // Resident model-axis block of a param: for TwoD sharding the
        // resident shard is additionally data-sliced, so a data-axis
        // all-gather reconstructs the *block* (never the full param).
        // Lane-routed: under microbatched overlap the previous microbatch's
        // grad reduces may still be in flight on this data subgroup, and a
        // host-thread ring op concurrent with them would corrupt the ring —
        // the lane's FIFO serializes this gather behind them instead.
        let blk = |name: &str| -> anyhow::Result<HostTensor> {
            let i = bp.index(name)?;
            let e = &self.plan.entries[i];
            let mut t = shards[i].clone();
            if let Some((dim, _)) = e.spec.dim_for(MeshAxis::Data) {
                let g = dg_arc.clone();
                let shard = t;
                t = runner
                    .sync("lane/block_gather", move || all_gather_axis(&g, dr, &shard, dim));
            }
            self.note_param_peak(t.elements());
            Ok(t)
        };
        let run = |seg: &str, inputs: Vec<HostTensor>| -> anyhow::Result<Vec<HostTensor>> {
            let exe = bp
                .segments
                .get(seg)
                .ok_or_else(|| anyhow::anyhow!("missing block segment '{seg}'"))?;
            // format! only when recording — the off path stays alloc-free
            let _sp = if self.tracer.is_enabled() {
                Some(self.tracer.span(&format!("seg/{seg}")))
            } else {
                None
            };
            let t0 = Instant::now();
            let outs = exe.run(inputs)?;
            self.timing.execute.add_since(t0);
            Ok(outs)
        };
        // The ordered collective schedule: every host-inserted model-axis
        // reduction advances a cursor through the manifest contract, and
        // point/op/payload must match — a stale or hand-edited contract
        // fails loudly instead of silently diverging.
        let sched = &bp.spec.collectives;
        let cursor = Cell::new(0usize);
        let ar = |point: &str, t: &HostTensor| -> anyhow::Result<HostTensor> {
            let c = sched.get(cursor.get()).ok_or_else(|| {
                anyhow::anyhow!("block schedule exhausted at point '{point}'")
            })?;
            anyhow::ensure!(
                c.point == point && c.elems == t.elements(),
                "block schedule mismatch at index {}: manifest ({}, {} elems) vs \
                 executor ({point}, {} elems)",
                cursor.get(),
                c.point,
                c.elems,
                t.elements()
            );
            cursor.set(cursor.get() + 1);
            let _sp = if self.tracer.is_enabled() {
                Some(
                    self.tracer
                        .span(&format!("coll/{}", c.point))
                        .arg("axis", "model")
                        .arg("op", c.op.as_str())
                        .arg("bytes", c.elems * 4),
                )
            } else {
                None
            };
            let t0 = Instant::now();
            let out = all_reduce_tensor_op(mg, mr, t, parse_reduce_op(&c.op)?);
            self.timing.collectives_model.add_since(t0);
            Ok(out)
        };

        // ---- forward ----
        let emb = blk("token_embed")?;
        let rp = blk("decoder.relpos_bias")?;
        let fwd = run("fwd_embed", vec![emb.clone(), tokens.clone(), shard_t.clone()])?;
        let mut x = ar("embed_out", &fwd[0])?;
        let mut x_attn_in = Vec::with_capacity(nl);
        let mut x_mlp_in = Vec::with_capacity(nl);
        for i in 0..nl {
            x_attn_in.push(x.clone());
            let outs = run(
                "fwd_attn",
                vec![
                    x.clone(),
                    blk(&layer(i, "pre_attn_norm.scale"))?,
                    blk(&layer(i, "self_attn.wq"))?,
                    blk(&layer(i, "self_attn.wk"))?,
                    blk(&layer(i, "self_attn.wv"))?,
                    blk(&layer(i, "self_attn.wo"))?,
                    rp.clone(),
                ],
            )?;
            x = x.add(&ar(&format!("layer_{i}.attn_out"), &outs[0])?);
            x_mlp_in.push(x.clone());
            let outs = run(
                "fwd_mlp",
                vec![
                    x.clone(),
                    blk(&layer(i, "pre_mlp_norm.scale"))?,
                    blk(&layer(i, "mlp.wi_0"))?,
                    blk(&layer(i, "mlp.wi_1"))?,
                    blk(&layer(i, "mlp.wo"))?,
                ],
            )?;
            x = x.add(&ar(&format!("layer_{i}.mlp_out"), &outs[0])?);
        }
        let fnorm = blk("decoder.final_norm.scale")?;
        let lout = run("fwd_loss_logits", vec![x.clone(), fnorm.clone(), emb.clone()])?;
        let (z, lmax) = (lout[0].clone(), lout[1].clone());
        let gmax = ar("logits_max", &lmax)?;
        let fin = run(
            "fwd_loss_finalize",
            vec![z.clone(), gmax.clone(), targets.clone(), weights.clone(), shard_t.clone()],
        )?;
        let se = ar("softmax_sum", &fin[0])?;
        let tl = ar("target_logit", &fin[1])?;
        let claim = ar("argmax_claim", &fin[2])?;
        let sc = run(
            "fwd_loss_final",
            vec![se.clone(), tl.clone(), claim, gmax.clone(), targets.clone(), weights.clone()],
        )?;
        let (loss_sum, weight_sum, correct_sum) =
            (sc[0].first_f32(), sc[1].first_f32(), sc[2].first_f32());

        // ---- backward (rematerializes from saved segment inputs) ----
        let mut grads: Vec<Option<HostTensor>> = vec![None; self.plan.entries.len()];
        let db = run(
            "bwd_loss_final",
            vec![se, tl, gmax.clone(), targets.clone(), weights.clone()],
        )?;
        let dz = run(
            "bwd_loss_finalize",
            vec![
                z,
                gmax,
                targets,
                weights,
                shard_t.clone(),
                db[0].clone(),
                db[1].clone(),
            ],
        )?;
        let dl = run("bwd_loss_logits", vec![x, fnorm, emb.clone(), dz[0].clone()])?;
        grads[bp.index("decoder.final_norm.scale")?] = Some(dl[1].clone());
        grads[bp.index("token_embed")?] = Some(dl[2].clone());
        let mut d_x = ar("d_final", &dl[0])?;
        let rp_i = bp.index("decoder.relpos_bias")?;
        for i in (0..nl).rev() {
            let outs = run(
                "bwd_mlp",
                vec![
                    x_mlp_in[i].clone(),
                    blk(&layer(i, "pre_mlp_norm.scale"))?,
                    blk(&layer(i, "mlp.wi_0"))?,
                    blk(&layer(i, "mlp.wi_1"))?,
                    blk(&layer(i, "mlp.wo"))?,
                    d_x.clone(),
                ],
            )?;
            grads[bp.index(&layer(i, "pre_mlp_norm.scale"))?] = Some(outs[1].clone());
            grads[bp.index(&layer(i, "mlp.wi_0"))?] = Some(outs[2].clone());
            grads[bp.index(&layer(i, "mlp.wi_1"))?] = Some(outs[3].clone());
            grads[bp.index(&layer(i, "mlp.wo"))?] = Some(outs[4].clone());
            d_x = d_x.add(&ar(&format!("layer_{i}.d_mlp"), &outs[0])?);
            let outs = run(
                "bwd_attn",
                vec![
                    x_attn_in[i].clone(),
                    blk(&layer(i, "pre_attn_norm.scale"))?,
                    blk(&layer(i, "self_attn.wq"))?,
                    blk(&layer(i, "self_attn.wk"))?,
                    blk(&layer(i, "self_attn.wv"))?,
                    blk(&layer(i, "self_attn.wo"))?,
                    rp.clone(),
                    d_x.clone(),
                ],
            )?;
            grads[bp.index(&layer(i, "pre_attn_norm.scale"))?] = Some(outs[1].clone());
            grads[bp.index(&layer(i, "self_attn.wq"))?] = Some(outs[2].clone());
            grads[bp.index(&layer(i, "self_attn.wk"))?] = Some(outs[3].clone());
            grads[bp.index(&layer(i, "self_attn.wv"))?] = Some(outs[4].clone());
            grads[bp.index(&layer(i, "self_attn.wo"))?] = Some(outs[5].clone());
            // the relpos table is shared across layers: host-sum the blocks
            grads[rp_i] = Some(match grads[rp_i].take() {
                Some(prev) => prev.add(&outs[6]),
                None => outs[6].clone(),
            });
            d_x = d_x.add(&ar(&format!("layer_{i}.d_attn"), &outs[0])?);
        }
        let de = run("bwd_embed", vec![emb, tokens, shard_t, d_x])?;
        let emb_i = bp.index("token_embed")?;
        grads[emb_i] = Some(grads[emb_i].take().unwrap().add(&de[0]));

        // ---- fused trailing AR of the model-replicated (norm-scale)
        // grads: one flat payload, split back after the reduction ----
        {
            let c = sched.get(cursor.get()).ok_or_else(|| {
                anyhow::anyhow!("block schedule exhausted before 'replicated_grads'")
            })?;
            anyhow::ensure!(
                c.point == "replicated_grads" && parse_reduce_op(&c.op)? == ReduceOp::Sum,
                "block schedule must end with a summed 'replicated_grads', got '{}'",
                c.point
            );
            cursor.set(cursor.get() + 1);
            let mut flat = Vec::with_capacity(c.elems);
            for name in &bp.spec.replicated_grads {
                flat.extend_from_slice(grads[bp.index(name)?].as_ref().unwrap().as_f32());
            }
            anyhow::ensure!(
                flat.len() == c.elems,
                "replicated_grads payload: manifest {} elems, executor {}",
                c.elems,
                flat.len()
            );
            let _sp = if self.tracer.is_enabled() {
                Some(
                    self.tracer
                        .span("coll/replicated_grads")
                        .arg("axis", "model")
                        .arg("op", c.op.as_str())
                        .arg("bytes", c.elems * 4),
                )
            } else {
                None
            };
            let t0 = Instant::now();
            let red = mg.all_reduce(mr, flat);
            self.timing.collectives_model.add_since(t0);
            let mut off = 0;
            for name in &bp.spec.replicated_grads {
                let i = bp.index(name)?;
                let g = grads[i].take().unwrap();
                let n = g.elements();
                grads[i] = Some(HostTensor::f32(g.shape.clone(), red[off..off + n].to_vec()));
                off += n;
            }
        }
        anyhow::ensure!(
            cursor.get() == sched.len(),
            "block collective schedule not fully consumed: {}/{} points",
            cursor.get(),
            sched.len()
        );
        let grads = grads
            .into_iter()
            .enumerate()
            .map(|(i, g)| {
                g.ok_or_else(|| {
                    let name = &self.plan.entries[i].name;
                    anyhow::anyhow!("block step produced no grad for '{name}'")
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        for g in &grads {
            self.note_param_peak(g.elements());
        }
        Ok((loss_sum, weight_sum, correct_sum, grads))
    }

    /// Distributed synchronized checkpoint: the coordinator declares the
    /// array layouts, then every owning host concurrently writes its
    /// disjoint `tstore` slice/block (all ranks are at the same step
    /// boundary, so the snapshot is globally consistent), then the
    /// coordinator commits atomically. No host gathers the full model.
    fn checkpoint_barrier(
        &self,
        rank: usize,
        step: u64,
        dir: &PathBuf,
        source: &BatchSource,
    ) -> anyhow::Result<()> {
        let _sp = self.tracer.span("checkpoint/save").arg("step", step);
        let mgr = CheckpointManager::new(dir.clone());
        let mesh = self.config.mesh;
        let scalar_spec = PartitionSpec::replicated(1);
        // Phase 1: coordinator declares every array.
        if rank == 0 {
            let writer = mgr.begin_sharded(step)?;
            let host0 = self.hosts[0].lock().unwrap();
            for e in &self.plan.entries {
                writer.declare(&format!("params/{}", e.name), &e.shape, &e.spec)?;
                for (slot, len) in host0.optimizer.state_slot_lens(&e.name) {
                    let name = format!("optstate/{}/{slot}", e.name);
                    if len == e.shard_elems() {
                        // elementwise slot: sharded exactly like the param
                        writer.declare(&name, &e.shape, &e.spec)?;
                    } else {
                        // factored stats: topology-local
                        writer.declare_local(&name, &mesh)?;
                    }
                }
            }
            writer.declare("optstate/trainstate/step", &[1], &scalar_spec)?;
        }
        self.colls.barrier(rank);
        // Phase 2: every owner writes its blocks, concurrently.
        let writer = mgr.sharded_writer(step);
        {
            let host = self.hosts[rank].lock().unwrap();
            for (e, shard) in self.plan.entries.iter().zip(&host.shards) {
                if !e.spec.owns(&mesh, rank) {
                    continue;
                }
                writer.write_block(&format!("params/{}", e.name), &e.spec, &mesh, rank, shard)?;
                for (slot, data) in host.optimizer.state_slices(&e.name) {
                    let name = format!("optstate/{}/{slot}", e.name);
                    if data.len() == e.shard_elems() {
                        let t = HostTensor::f32(e.shard_shape.clone(), data.to_vec());
                        writer.write_block(&name, &e.spec, &mesh, rank, &t)?;
                    } else {
                        writer.write_local(&name, &e.spec, &mesh, rank, data)?;
                    }
                }
            }
            if rank == 0 {
                writer.write_block(
                    "optstate/trainstate/step",
                    &scalar_spec,
                    &mesh,
                    0,
                    &HostTensor::f32(vec![1], vec![step as f32]),
                )?;
            }
        }
        self.colls.barrier(rank);
        // Phase 3: coordinator commits (pipeline states + metadata + rename).
        if rank == 0 {
            let pipeline = source.pipeline_states(mesh.data);
            mgr.commit_sharded(step, self.plan.entries.len(), mesh, pipeline.as_deref())?;
            // S10 injection point: flip a byte in a committed chunk, so the
            // CRC walk-back path in `restore_latest` is exercised against a
            // real (renamed, metadata-complete) checkpoint dir.
            if let Some(array) = crate::faults::checkpoint_corrupt_target(step) {
                let ckpt = dir.join(format!("ckpt-{step:08}"));
                if let Err(e) = crate::faults::corrupt_checkpoint_chunk(&ckpt, &array) {
                    eprintln!("warning: corrupt_checkpoint injection failed: {e:#}");
                }
            }
        }
        self.colls.barrier(rank);
        Ok(())
    }

    /// Restore params + optimizer state + step + data-pipeline position
    /// from the latest *valid* checkpoint — with resharding: every host
    /// range-reads exactly its own blocks, whatever mesh the checkpoint
    /// was saved on.
    ///
    /// Resilience (S10): stale `ckpt-*.tmp` leftovers are swept first,
    /// and a checkpoint that fails to restore (CRC-corrupt chunk,
    /// truncated array, unreadable pipeline state) is *quarantined* —
    /// renamed to `ckpt-<n>.corrupt`, loudly, with the cause — and the
    /// walk-back retries the previous retained step. The error surfaces
    /// only when no retained step restores. Each quarantine increments
    /// the `train/quarantined_ckpts` counter.
    pub fn restore_latest(&mut self, dir: &PathBuf) -> anyhow::Result<u64> {
        let _sp = self.tracer.span("checkpoint/restore");
        let mgr = CheckpointManager::new(dir.clone());
        mgr.sweep_tmp();
        loop {
            let step = mgr.latest().ok_or_else(|| {
                anyhow::anyhow!("no valid checkpoint in {}", dir.display())
            })?;
            match self.restore_step(&mgr, step) {
                Ok(()) => {
                    self.start_step = step;
                    return Ok(step);
                }
                Err(e) => {
                    let dst = mgr.quarantine(step).map_err(|qe| {
                        anyhow::anyhow!(
                            "checkpoint step {step} is damaged ({e:#}) and could \
                             not be quarantined: {qe}"
                        )
                    })?;
                    self.counters.inc("train/quarantined_ckpts");
                    eprintln!(
                        "warning: checkpoint step {step} failed to restore ({e:#}); \
                         quarantined to {} and falling back to the previous \
                         retained step",
                        dst.display()
                    );
                }
            }
        }
    }

    /// One restore attempt at a specific step (the body of
    /// [`Self::restore_latest`]; does not touch `start_step`).
    fn restore_step(&mut self, mgr: &CheckpointManager, step: u64) -> anyhow::Result<()> {
        let mesh = self.config.mesh;
        // Pre-refactor TwoD checkpoints stored optimizer moments as one
        // flat chunked vector ('optstate/flat/<slot>'), which does not map
        // onto per-parameter blocks — warn once instead of restoring
        // silently-zeroed moments without notice.
        for slot in ["m", "v", "velocity"] {
            if mgr.has_optstate(step, &format!("flat/{slot}")) {
                eprintln!(
                    "warning: checkpoint at step {step} carries pre-refactor flat \
                     optimizer state (optstate/flat/*), which the sharded trainer \
                     does not restore; optimizer moments start fresh"
                );
                break;
            }
        }
        for (h, hs) in self.hosts.iter().enumerate() {
            let mut host = hs.lock().unwrap();
            let HostState { shards, optimizer } = &mut *host;
            for (i, e) in self.plan.entries.iter().enumerate() {
                let ranges = e.spec.host_ranges(&mesh, h, &e.shape);
                shards[i] = mgr
                    .restore_param_range(step, &e.name, &ranges)
                    .map_err(|err| anyhow::anyhow!("restoring param {}: {err}", e.name))?;
                for (slot, cur_len) in optimizer.state_slot_lens(&e.name) {
                    let name = format!("{}/{slot}", e.name);
                    if !mgr.has_optstate(step, &name) {
                        continue; // params-only checkpoint (e.g. legacy-converted)
                    }
                    let data = if cur_len == e.shard_elems() {
                        // elementwise slot: range-read at this host's block
                        // (degrade with a warning on alien layouts, e.g. a
                        // legacy flat 1-D array for a rank-2 parameter)
                        match mgr.restore_optstate_range(step, &name, &ranges) {
                            Ok(t) => Some(t.as_f32().to_vec()),
                            Err(err) => {
                                if h == 0 {
                                    eprintln!(
                                        "warning: optimizer state '{name}' not \
                                         restorable at this sharding ({err:#}); \
                                         slot starts fresh"
                                    );
                                }
                                None
                            }
                        }
                    } else {
                        // factored stats: only the topology-local layout can
                        // hold them. A mesh mismatch on that layout is a
                        // hard, documented error; any other layout is a
                        // legacy format we reset with a warning.
                        match mgr.optstate_layout(step, &name)? {
                            crate::checkpoint::ArrayLayout::Local { .. } => {
                                Some(mgr.restore_optstate_local(
                                    step,
                                    &name,
                                    &mesh,
                                    block_coords(&e.spec, &mesh, h),
                                )?)
                            }
                            _ => {
                                if h == 0 {
                                    eprintln!(
                                        "warning: factored optimizer state '{name}' \
                                         has a pre-refactor layout; slot starts fresh"
                                    );
                                }
                                None
                            }
                        }
                    };
                    if let Some(data) = data {
                        optimizer.restore_state_vector(&e.name, slot, data);
                    }
                }
            }
        }
        // Pipeline state is per data row; a changed row count falls back to
        // the coarse `start_step * batch` positioning (exact for caches).
        self.restored_pipeline = match mgr.restore_pipeline(step)? {
            Some(states) if states.len() == mesh.data => Some(states),
            Some(states) => {
                eprintln!(
                    "note: checkpoint has {} data-row pipeline states, mesh {} has {} rows; \
                     using coarse stream positioning",
                    states.len(),
                    mesh,
                    mesh.data
                );
                None
            }
            None => None,
        };
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn device() -> DeviceHandle {
        DeviceHandle::spawn().unwrap()
    }

    #[test]
    fn loss_decreases_on_fixed_batch_distribution() {
        let arts = Artifacts::load_default().unwrap();
        let dev = device();
        let mut cfg = TrainerConfig::quick("t5-nano-dec", 12);
        cfg.schedule = Schedule::Constant(2e-3);
        let trainer = Trainer::new(&arts, &dev, cfg).unwrap();
        let summary = trainer.train(&BatchSource::Synthetic { seed: 7 }).unwrap();
        assert_eq!(summary.history.len(), 12);
        assert!(
            summary.final_loss() < summary.first_loss(),
            "loss did not decrease: {} -> {}",
            summary.first_loss(),
            summary.final_loss()
        );
        dev.shutdown();
    }

    #[test]
    fn multi_host_1d_matches_single_host_global_batch() {
        // 2 data rows with the same per-host batch == global batch 2x; loss
        // must sync over the data axis (smoke: runs and improves).
        let arts = Artifacts::load_default().unwrap();
        let dev = device();
        let mut cfg = TrainerConfig::quick("t5-nano-dec", 6);
        cfg.mesh = Mesh::new(2, 1);
        let trainer = Trainer::new(&arts, &dev, cfg).unwrap();
        let summary = trainer.train(&BatchSource::Synthetic { seed: 3 }).unwrap();
        assert!(summary.final_loss() < summary.first_loss());
        assert!(summary.comm_bytes > 0);
        assert!(summary.data_axis_bytes > 0);
        assert_eq!(summary.model_axis_bytes, 0, "model axis is size 1");
        dev.shutdown();
    }

    #[test]
    fn zero3_matches_1d_losses_exactly() {
        // E4: 2D (ZeRO-3) must reproduce the 1D loss trajectory with an
        // elementwise optimizer.
        let arts = Artifacts::load_default().unwrap();
        let dev = device();
        let mk = |strategy| {
            let mut cfg = TrainerConfig::quick("t5-nano-dec", 5);
            cfg.mesh = Mesh::new(2, 1);
            cfg.strategy = strategy;
            cfg.seed = 11;
            Trainer::new(&arts, &dev, cfg).unwrap()
        };
        let s1 = mk(ParamStrategy::OneD)
            .train(&BatchSource::Synthetic { seed: 5 })
            .unwrap();
        let s2 = mk(ParamStrategy::TwoD)
            .train(&BatchSource::Synthetic { seed: 5 })
            .unwrap();
        for (a, b) in s1.history.iter().zip(&s2.history) {
            assert!(
                (a.loss - b.loss).abs() < 1e-4,
                "step {}: 1D {} vs 2D {}",
                a.step,
                a.loss,
                b.loss
            );
        }
        // and ZeRO holds ~1/2 the optimizer state AND parameters per host
        let t1 = mk(ParamStrategy::OneD);
        let t2 = mk(ParamStrategy::TwoD);
        assert!(
            t2.optimizer_state_floats(0) * 2 <= t1.optimizer_state_floats(0) + 16
        );
        assert!(
            t2.resident_param_floats(0) * 2
                <= t1.resident_param_floats(0) + t2.plan.largest_param_elems()
        );
        dev.shutdown();
    }

    #[test]
    fn checkpoint_and_resume_continue_exactly() {
        let arts = Artifacts::load_default().unwrap();
        let dev = device();
        let dir = std::env::temp_dir().join(format!("trainer_ckpt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);

        // run 6 steps straight
        let mut cfg = TrainerConfig::quick("t5-nano-dec", 6);
        cfg.seed = 2;
        cfg.schedule = Schedule::Constant(1e-3);
        let t_full = Trainer::new(&arts, &dev, cfg.clone()).unwrap();
        let full = t_full.train(&BatchSource::Synthetic { seed: 9 }).unwrap();

        // run 3 + checkpoint + restore + 3
        let mut cfg_a = cfg.clone();
        cfg_a.steps = 3;
        cfg_a.checkpoint_every = Some(3);
        cfg_a.checkpoint_dir = Some(dir.clone());
        let t_a = Trainer::new(&arts, &dev, cfg_a).unwrap();
        t_a.train(&BatchSource::Synthetic { seed: 9 }).unwrap();

        let mut cfg_b = cfg;
        cfg_b.steps = 3;
        let mut t_b = Trainer::new(&arts, &dev, cfg_b).unwrap();
        let resumed_step = t_b.restore_latest(&dir).unwrap();
        assert_eq!(resumed_step, 3);
        let resumed = t_b.train(&BatchSource::Synthetic { seed: 9 }).unwrap();

        // steps 3..6 must match the uninterrupted run exactly
        for (a, b) in full.history[3..].iter().zip(&resumed.history) {
            assert_eq!(a.step, b.step);
            assert!(
                (a.loss - b.loss).abs() < 1e-5,
                "step {}: {} vs {}",
                a.step,
                a.loss,
                b.loss
            );
        }
        std::fs::remove_dir_all(&dir).ok();
        dev.shutdown();
    }
}

#[cfg(test)]
mod feature_tests {
    use super::*;

    #[test]
    fn grad_clip_keeps_training_stable_and_changes_trajectory() {
        let arts = Artifacts::load_default().unwrap();
        let dev = DeviceHandle::spawn().unwrap();
        let mut base = TrainerConfig::quick("t5-nano-dec", 5);
        base.schedule = Schedule::Constant(1e-3);
        let unclipped = Trainer::new(&arts, &dev, base.clone())
            .unwrap()
            .train(&BatchSource::Synthetic { seed: 2 })
            .unwrap();
        let mut clipped_cfg = base.clone();
        clipped_cfg.grad_clip_norm = Some(0.05); // tight: always active
        let clipped = Trainer::new(&arts, &dev, clipped_cfg)
            .unwrap()
            .train(&BatchSource::Synthetic { seed: 2 })
            .unwrap();
        // both runs train; trajectories differ because the clip is active
        assert!(clipped.final_loss().is_finite());
        assert!(
            (clipped.final_loss() - unclipped.final_loss()).abs() > 1e-6,
            "clip had no effect"
        );
        dev.shutdown();
    }

    #[test]
    fn grad_clip_identical_across_strategies() {
        // clipping is computed on the GLOBAL gradient, so 1D and 2D still
        // agree step-for-step with clipping enabled.
        let arts = Artifacts::load_default().unwrap();
        let dev = DeviceHandle::spawn().unwrap();
        let mk = |strategy| {
            let mut cfg = TrainerConfig::quick("t5-nano-dec", 4);
            cfg.mesh = Mesh::new(2, 1);
            cfg.strategy = strategy;
            cfg.grad_clip_norm = Some(0.1);
            cfg.schedule = Schedule::Constant(1e-3);
            Trainer::new(&arts, &dev, cfg).unwrap()
        };
        let a = mk(ParamStrategy::OneD)
            .train(&BatchSource::Synthetic { seed: 4 })
            .unwrap();
        let b = mk(ParamStrategy::TwoD)
            .train(&BatchSource::Synthetic { seed: 4 })
            .unwrap();
        for (x, y) in a.history.iter().zip(&b.history) {
            assert!((x.loss - y.loss).abs() < 1e-4, "step {}: {} vs {}", x.step, x.loss, y.loss);
        }
        dev.shutdown();
    }

    #[test]
    fn weight_decay_shrinks_param_norm() {
        let arts = Artifacts::load_default().unwrap();
        let dev = DeviceHandle::spawn().unwrap();
        let mut cfg = TrainerConfig::quick("t5-nano-dec", 6);
        cfg.schedule = Schedule::Constant(1e-4); // tiny lr: decay dominates
        cfg.weight_decay = Some(5.0);
        let trainer = Trainer::new(&arts, &dev, cfg.clone()).unwrap();
        let norm_before: f64 = trainer
            .params()
            .values()
            .map(|t| t.norm().powi(2))
            .sum::<f64>()
            .sqrt();
        trainer.train(&BatchSource::Synthetic { seed: 1 }).unwrap();
        let norm_after: f64 = trainer
            .params()
            .values()
            .map(|t| t.norm().powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(
            norm_after < norm_before * 0.999,
            "decay did not shrink params: {norm_before} -> {norm_after}"
        );
        dev.shutdown();
    }

    #[test]
    fn timing_breakdown_accounts_for_step_time() {
        let arts = Artifacts::load_default().unwrap();
        let dev = DeviceHandle::spawn().unwrap();
        let cfg = TrainerConfig::quick("t5-nano-dec", 3);
        let trainer = Trainer::new(&arts, &dev, cfg).unwrap();
        let summary = trainer.train(&BatchSource::Synthetic { seed: 0 }).unwrap();
        let rows = trainer.timing.rows();
        let phase_total: f64 = rows.iter().map(|(_, s)| s).sum();
        assert!(phase_total > 0.0);
        // phases cover the bulk of wall time (single host, no overlap)
        assert!(
            phase_total > 0.5 * summary.wall_seconds,
            "phases {phase_total} vs wall {}",
            summary.wall_seconds
        );
        // execute dominates on this workload
        assert_eq!(rows[0].0, "execute");
        dev.shutdown();
    }

    #[test]
    fn per_axis_traffic_counters_populated() {
        // 2x2 mesh: gradient sync moves data-axis bytes, parameter
        // gathers + batch broadcast move model-axis bytes, and the
        // CounterSet surfaces both.
        let arts = Artifacts::load_default().unwrap();
        let dev = DeviceHandle::spawn().unwrap();
        let mut cfg = TrainerConfig::quick("t5-nano-dec", 2);
        cfg.mesh = Mesh::new(2, 2);
        cfg.strategy = ParamStrategy::TwoD;
        let trainer = Trainer::new(&arts, &dev, cfg).unwrap();
        let summary = trainer.train(&BatchSource::Synthetic { seed: 1 }).unwrap();
        assert!(summary.data_axis_bytes > 0);
        assert!(summary.model_axis_bytes > 0);
        assert_eq!(
            trainer.counters.get("train/data_axis_bytes"),
            summary.data_axis_bytes
        );
        assert_eq!(
            trainer.counters.get("train/model_axis_bytes"),
            summary.model_axis_bytes
        );
        assert!(trainer.counters.get("train/data_axis_ops") > 0);
        // timing attributes both axes (real collectives took real time)
        assert!(trainer.timing.collectives_data.seconds() > 0.0);
        assert!(trainer.timing.collectives_model.seconds() > 0.0);
        dev.shutdown();
    }
}
