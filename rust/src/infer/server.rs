//! JSONL request/response serving loop (the `t5x serve` subcommand's
//! stdin transport), riding the same [`Gateway`] admission queue and
//! replica router as the HTTP front end.
//!
//! Protocol: one JSON object per input line —
//!
//! ```json
//! {"id": 1, "prompt": [5, 9, 11], "max_tokens": 8,
//!  "method": "sample", "temperature": 0.8, "top_k": 20, "top_p": 0.95,
//!  "seed": 7, "priority": 1, "deadline_ms": 250}
//! ```
//!
//! Only `prompt` is required: `id` defaults to an auto-incremented
//! counter, `method` to `"greedy"`, `max_tokens` to the server default,
//! `priority` to 0, `deadline_ms` to none. Responses are emitted *as
//! requests complete* (not in submission order):
//!
//! ```json
//! {"id": 1, "tokens": [12, 4, 1], "steps": 3, "replica": 0,
//!  "queue_ms": 0.1, "ttft_ms": 2.0, "latency_ms": 5.2}
//! ```
//!
//! A background thread reads the input while the replicas decode, so new
//! requests join running batches mid-flight. Malformed lines produce
//! `{"error": ...}` responses and do not stop the loop. Gateway
//! backpressure ([`AdmitError::QueueFull`]) is handled by *holding* the
//! request and retrying as outcomes drain — the stdin transport blocks
//! instead of dropping, so piping a large request file through `serve`
//! never loses work, while HTTP clients doing the same get 429s.

use std::io::{BufRead, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use super::decoding::DecodeMethod;
use super::engine::{InferRequest, InferResult};
use crate::serve::{AdmitError, Gateway, ServeOutcome, SubmitOpts};
use crate::util::json::Json;
use crate::util::threads::Pipe;

/// Parse one request line/body (shared by the JSONL and HTTP
/// transports). `auto_id` is used when the payload carries no `"id"`;
/// `default_max_tokens` when it carries no `"max_tokens"`.
pub fn parse_request(
    line: &str,
    auto_id: u64,
    default_max_tokens: usize,
) -> anyhow::Result<(InferRequest, SubmitOpts)> {
    let v = Json::parse(line.trim())?;
    let prompt: Vec<i32> = v
        .get("prompt")
        .and_then(|p| p.as_arr())
        .ok_or_else(|| anyhow::anyhow!("request needs a \"prompt\" array of token ids"))?
        .iter()
        .map(|x| {
            let n = x
                .as_i64()
                .ok_or_else(|| anyhow::anyhow!("non-numeric token id in prompt"))?;
            i32::try_from(n)
                .map_err(|_| anyhow::anyhow!("token id {n} out of i32 range"))
        })
        .collect::<anyhow::Result<_>>()?;
    let id = match v.get("id") {
        None => auto_id,
        Some(x) => {
            let n = x.as_i64().unwrap_or(-1);
            anyhow::ensure!(n >= 0, "\"id\" must be a non-negative integer");
            n as u64
        }
    };
    let max_tokens =
        v.get("max_tokens").and_then(|x| x.as_usize()).unwrap_or(default_max_tokens);
    let method = match v.get("method").and_then(|m| m.as_str()).unwrap_or("greedy") {
        "greedy" => DecodeMethod::Greedy,
        "sample" => DecodeMethod::Sample {
            temperature: v
                .get("temperature")
                .and_then(|x| x.as_f64())
                .unwrap_or(1.0) as f32,
            top_k: v.get("top_k").and_then(|x| x.as_usize()).unwrap_or(0),
            top_p: v.get("top_p").and_then(|x| x.as_f64()).unwrap_or(1.0) as f32,
            seed: v.get("seed").and_then(|x| x.as_i64()).unwrap_or(0) as u64,
        },
        other => anyhow::bail!("unknown method '{other}' (greedy|sample)"),
    };
    let priority = match v.get("priority") {
        None => 0,
        Some(x) => x
            .as_i64()
            .ok_or_else(|| anyhow::anyhow!("\"priority\" must be an integer"))?,
    };
    let deadline = match v.get("deadline_ms") {
        None => None,
        Some(x) => {
            let ms = x
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("\"deadline_ms\" must be a number"))?;
            anyhow::ensure!(ms >= 0.0, "\"deadline_ms\" must be >= 0");
            Some(Duration::from_secs_f64(ms / 1e3))
        }
    };
    Ok((
        InferRequest { id, prompt, max_tokens, method },
        SubmitOpts { priority, deadline },
    ))
}

/// Render one completed request as a response line (engine-internal
/// timing; used when driving an [`super::InferEngine`] directly).
pub fn result_to_json(r: &InferResult) -> Json {
    let mut pairs = vec![
        ("id", Json::num(r.id as f64)),
        (
            "tokens",
            Json::Arr(r.tokens.iter().map(|&t| Json::num(t as f64)).collect()),
        ),
        ("steps", Json::num(r.tokens.len() as f64)),
        ("queue_ms", Json::num(r.queue_seconds * 1e3)),
        ("latency_ms", Json::num(r.latency_seconds * 1e3)),
    ];
    if let Some(t) = r.ttft_seconds {
        pairs.push(("ttft_ms", Json::num(t * 1e3)));
    }
    Json::obj(pairs)
}

/// Render a gateway outcome as a response line. Timing fields here are
/// client-true (they include gateway queue wait); `id` is the client's.
pub fn outcome_to_json(o: &ServeOutcome) -> Json {
    match o {
        ServeOutcome::Done {
            client_id,
            result,
            replica,
            queue_ms,
            ttft_ms,
            latency_ms,
        } => {
            let mut pairs = vec![
                ("id", Json::num(*client_id as f64)),
                (
                    "tokens",
                    Json::Arr(
                        result.tokens.iter().map(|&t| Json::num(t as f64)).collect(),
                    ),
                ),
                ("steps", Json::num(result.tokens.len() as f64)),
                ("replica", Json::num(*replica as f64)),
                ("queue_ms", Json::num(*queue_ms)),
                ("latency_ms", Json::num(*latency_ms)),
            ];
            if let Some(t) = ttft_ms {
                pairs.push(("ttft_ms", Json::num(*t)));
            }
            Json::obj(pairs)
        }
        ServeOutcome::Shed { client_id, reason, waited_ms } => Json::obj(vec![
            ("id", Json::num(*client_id as f64)),
            ("error", Json::str(format!("request shed: {}", reason.as_str()))),
            ("shed", Json::str(reason.as_str())),
            ("waited_ms", Json::num(*waited_ms)),
        ]),
        ServeOutcome::Failed { client_id, error } => Json::obj(vec![
            ("id", Json::num(*client_id as f64)),
            ("error", Json::str(error.clone())),
        ]),
    }
}

/// Totals reported when the input stream closes (or a drain stops the
/// loop).
#[derive(Debug, Clone)]
pub struct ServeSummary {
    /// Requests accepted into the gateway admission queue.
    pub requests: u64,
    /// Lines rejected at parse time or by admission validation.
    pub errors: u64,
    /// Requests that completed with tokens.
    pub completed: u64,
    /// Requests shed from the queue (deadline expiry / draining).
    pub shed: u64,
    /// Client-true queue-wait percentiles over completed requests (ms).
    pub queue_ms_p50: f64,
    pub queue_ms_p99: f64,
}

/// How often the loop re-polls input/stop while waiting for outcomes.
const POLL: Duration = Duration::from_millis(50);

/// Drive the gateway from a line-oriented reader until EOF (or `stop`),
/// writing one response line per outcome to `output`. The reader runs on
/// a background thread so requests arriving mid-decode join running
/// batches; admission backpressure blocks the reader (held-request
/// retry) instead of dropping lines. Setting `stop` (SIGINT / drain)
/// stops admitting, waits for in-flight requests, and returns.
pub fn serve<R, W>(
    gateway: &Gateway,
    input: R,
    mut output: W,
    default_max_tokens: usize,
    stop: Option<Arc<AtomicBool>>,
) -> anyhow::Result<ServeSummary>
where
    R: BufRead + Send + 'static,
    W: Write,
{
    let (line_tx, line_rx) = Pipe::<String>::bounded(256);
    let eof = Arc::new(AtomicBool::new(false));
    let eof_w = eof.clone();
    std::thread::Builder::new()
        .name("serve-reader".into())
        .spawn(move || {
            for line in input.lines() {
                let Ok(line) = line else { break };
                if line.trim().is_empty() {
                    continue;
                }
                if !line_tx.send(line) {
                    break; // server hung up
                }
            }
            eof_w.store(true, Ordering::Relaxed);
        })?;
    let (otx, orx) = mpsc::channel::<ServeOutcome>();
    let mut summary = ServeSummary {
        requests: 0,
        errors: 0,
        completed: 0,
        shed: 0,
        queue_ms_p50: 0.0,
        queue_ms_p99: 0.0,
    };
    let queue_hist = crate::obs::Histogram::new();
    let mut next_auto_id = 0u64;
    let mut outstanding = 0u64;
    // A request the gateway bounced with QueueFull: held and retried as
    // outcomes drain, pausing input consumption (backpressure all the
    // way to the pipe → the reader thread → the OS pipe buffer).
    let mut held: Option<(InferRequest, SubmitOpts)> = None;
    let submit = |req: InferRequest,
                      opts: SubmitOpts,
                      summary: &mut ServeSummary,
                      outstanding: &mut u64,
                      held: &mut Option<(InferRequest, SubmitOpts)>,
                      output: &mut W|
     -> anyhow::Result<()> {
        let id = req.id;
        match gateway.submit(req.clone(), opts.clone(), otx.clone()) {
            Ok(()) => {
                summary.requests += 1;
                *outstanding += 1;
            }
            Err(
                AdmitError::QueueFull { .. } | AdmitError::ShedLowPriority { .. },
            ) => {
                *held = Some((req, opts));
            }
            Err(e) => {
                summary.errors += 1;
                writeln!(
                    output,
                    "{}",
                    Json::obj(vec![
                        ("id", Json::num(id as f64)),
                        ("error", Json::str(format!("{e:#}"))),
                    ])
                )?;
            }
        }
        Ok(())
    };
    loop {
        let stopped = stop.as_ref().is_some_and(|s| s.load(Ordering::Relaxed));
        // Sample EOF *before* draining the pipe: if it was already set,
        // every line the reader will ever send is in the pipe, so an
        // empty pipe after the drain really means end of input (sampling
        // after would race a reader that sends its last line, then sets
        // the flag).
        let eof_seen = eof.load(Ordering::Relaxed);
        let mut input_drained = false;
        if stopped {
            if let Some((req, _)) = held.take() {
                summary.errors += 1;
                writeln!(
                    output,
                    "{}",
                    Json::obj(vec![
                        ("id", Json::num(req.id as f64)),
                        ("error", Json::str("gateway draining")),
                    ])
                )?;
            }
        } else {
            if let Some((req, opts)) = held.take() {
                submit(req, opts, &mut summary, &mut outstanding, &mut held, &mut output)?;
            }
            while held.is_none() {
                let Some(line) = line_rx.try_recv() else {
                    input_drained = true;
                    break;
                };
                match parse_request(&line, next_auto_id, default_max_tokens) {
                    Ok((req, opts)) => {
                        next_auto_id = next_auto_id.max(req.id).saturating_add(1);
                        submit(
                            req,
                            opts,
                            &mut summary,
                            &mut outstanding,
                            &mut held,
                            &mut output,
                        )?;
                    }
                    Err(e) => {
                        summary.errors += 1;
                        writeln!(
                            output,
                            "{}",
                            Json::obj(vec![("error", Json::str(format!("{e:#}")))])
                        )?;
                    }
                }
            }
        }
        let input_done = stopped || (eof_seen && input_drained && held.is_none());
        if input_done && outstanding == 0 {
            break;
        }
        // Responses must reach a request/reply client before we block,
        // or it deadlocks against a buffering writer.
        output.flush()?;
        let mut handle = |o: ServeOutcome,
                          summary: &mut ServeSummary,
                          output: &mut W|
         -> anyhow::Result<()> {
            outstanding = outstanding.saturating_sub(1);
            match &o {
                ServeOutcome::Done { queue_ms, .. } => {
                    summary.completed += 1;
                    queue_hist.record_ms(*queue_ms);
                }
                ServeOutcome::Shed { .. } => summary.shed += 1,
                ServeOutcome::Failed { .. } => summary.errors += 1,
            }
            writeln!(output, "{}", outcome_to_json(&o))?;
            Ok(())
        };
        match orx.recv_timeout(POLL) {
            Ok(o) => {
                handle(o, &mut summary, &mut output)?;
                while let Ok(o) = orx.try_recv() {
                    handle(o, &mut summary, &mut output)?;
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => unreachable!("otx held"),
        }
        output.flush()?;
    }
    output.flush()?;
    summary.queue_ms_p50 = queue_hist.p50();
    summary.queue_ms_p99 = queue_hist.p99();
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_and_full_requests() {
        let (r, o) = parse_request(r#"{"prompt": [5, 9]}"#, 7, 16).unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.prompt, vec![5, 9]);
        assert_eq!(r.max_tokens, 16);
        assert_eq!(r.method, DecodeMethod::Greedy);
        assert_eq!(o.priority, 0);
        assert_eq!(o.deadline, None);

        let (r, o) = parse_request(
            r#"{"id": 3, "prompt": [1], "max_tokens": 4, "method": "sample",
               "temperature": 0.5, "top_k": 8, "top_p": 0.9, "seed": 11,
               "priority": 2, "deadline_ms": 250}"#,
            0,
            16,
        )
        .unwrap();
        assert_eq!(r.id, 3);
        assert_eq!(
            r.method,
            DecodeMethod::Sample { temperature: 0.5, top_k: 8, top_p: 0.9, seed: 11 }
        );
        assert_eq!(o.priority, 2);
        assert_eq!(o.deadline, Some(Duration::from_millis(250)));
    }

    #[test]
    fn rejects_bad_requests() {
        assert!(parse_request("not json", 0, 8).is_err());
        assert!(parse_request(r#"{"max_tokens": 3}"#, 0, 8).is_err(), "missing prompt");
        assert!(parse_request(r#"{"prompt": [1], "method": "magic"}"#, 0, 8).is_err());
        assert!(parse_request(r#"{"prompt": ["x"]}"#, 0, 8).is_err());
        // out-of-range numbers must be rejected, not silently wrapped
        assert!(parse_request(r#"{"prompt": [4294967301]}"#, 0, 8).is_err());
        assert!(parse_request(r#"{"id": -1, "prompt": [1]}"#, 0, 8).is_err());
        assert!(parse_request(r#"{"prompt": [1], "deadline_ms": -5}"#, 0, 8).is_err());
        assert!(parse_request(r#"{"prompt": [1], "priority": "high"}"#, 0, 8).is_err());
    }

    #[test]
    fn result_lines_are_json() {
        let r = InferResult {
            id: 9,
            prompt_len: 3,
            tokens: vec![4, 5, 1],
            started_step: 0,
            finished_step: 3,
            queue_seconds: 0.001,
            latency_seconds: 0.01,
            ttft_seconds: Some(0.004),
        };
        let v = Json::parse(&result_to_json(&r).to_string()).unwrap();
        assert_eq!(v.get("id").unwrap().as_i64(), Some(9));
        assert_eq!(v.get("tokens").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("steps").unwrap().as_i64(), Some(3));
        let ttft = v.get("ttft_ms").unwrap().as_f64().unwrap();
        assert!((ttft - 4.0).abs() < 1e-9);
    }

    #[test]
    fn outcome_lines_are_json() {
        let done = ServeOutcome::Done {
            client_id: 42,
            result: InferResult {
                id: 7, // internal id: must NOT leak into the response
                prompt_len: 2,
                tokens: vec![4, 1],
                started_step: 0,
                finished_step: 2,
                queue_seconds: 0.0,
                latency_seconds: 0.01,
                ttft_seconds: Some(0.005),
            },
            replica: 1,
            queue_ms: 0.4,
            ttft_ms: Some(5.4),
            latency_ms: 10.4,
        };
        let v = Json::parse(&outcome_to_json(&done).to_string()).unwrap();
        assert_eq!(v.get("id").unwrap().as_i64(), Some(42));
        assert_eq!(v.get("replica").unwrap().as_i64(), Some(1));
        assert_eq!(v.get("tokens").unwrap().as_arr().unwrap().len(), 2);
        assert!(v.get("queue_ms").unwrap().as_f64().unwrap() > 0.0);

        let shed = ServeOutcome::Shed {
            client_id: 9,
            reason: crate::serve::ShedReason::DeadlineExpired,
            waited_ms: 125.0,
        };
        let v = Json::parse(&outcome_to_json(&shed).to_string()).unwrap();
        assert_eq!(v.get("id").unwrap().as_i64(), Some(9));
        assert_eq!(v.get("shed").unwrap().as_str(), Some("deadline_expired"));
        assert!(v.get("error").unwrap().as_str().unwrap().contains("deadline"));
    }
}
