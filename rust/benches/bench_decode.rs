//! Serving throughput: three-way naive / engine-rescore / engine-kv
//! comparison at several prompt+generation lengths.
//!
//! * **naive** reproduces the pre-engine `cmd_infer` shape: one request at
//!   a time through a full-batch rescore loop (useful work = one row, the
//!   other B-1 slots decode wasted duplicates, every step re-scores the
//!   whole prefix).
//! * **engine rescore** packs requests into the batch slots with
//!   mid-flight refills, but still drives the O(L^2) `decode_logits` HLO.
//! * **engine kv** is the same scheduler on the O(L) `prefill` /
//!   `decode_step` entrypoints ([B, 1] token input per step).
//!
//! Throughput counts *useful* tokens (requested tokens only), so
//! naive->rescore isolates the slot-utilization win and rescore->kv the
//! per-step compute win. Per-step decode seconds come from the engine
//! counters. The L=128 case asserts kv-mode throughput >= rescore-mode —
//! the ISSUE-5 acceptance bar (the gap widens with L; at L=32 the fixed
//! per-call overhead can still hide it).

use t5x::bench::Bench;
use t5x::infer::{DecodeMethod, DecodeMode, InferEngine, InferRequest};
use t5x::runtime::{Artifacts, DeviceHandle};
use t5x::util::json::Json;

/// Append one extra JSONL row to the shared bench log (serve latency
/// percentiles for the BENCH_<pr>.json trajectory).
fn append_row(path: &str, row: &Json) {
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .expect("open bench log");
    writeln!(f, "{row}").expect("append bench row");
}

fn submit_all(engine: &mut InferEngine, prompts: &[Vec<i32>], gen: usize) {
    for (i, p) in prompts.iter().enumerate() {
        engine
            .submit(InferRequest {
                id: i as u64,
                prompt: p.clone(),
                max_tokens: gen,
                method: DecodeMethod::Greedy,
            })
            .unwrap();
    }
}

fn main() {
    let arts = Artifacts::load_default().expect("make artifacts first");
    let device = DeviceHandle::spawn().unwrap();
    let mut bench = Bench::new("decode serving (infer)");
    // eos -1 never fires: every request decodes exactly `gen` tokens, so
    // all three rows do identical useful work.
    let eos = -1;
    let quick = bench.is_quick();
    // (model, prompt_len, gen_len): nano-dec is the short-sequence case
    // (L=32); nano-dec-l128 stretches the prefix to where O(L^2)
    // rescoring visibly loses (L=128).
    let cases = [
        ("t5-nano-dec", 3usize, if quick { 4usize } else { 8 }),
        ("t5-nano-dec-l128", 8, if quick { 32 } else { 96 }),
    ];
    for (model, plen, gen) in cases {
        let Some(m) = arts.models.get(model) else {
            println!("  SKIP {model}: not in this artifact dir (re-export)");
            continue;
        };
        let m = m.clone();
        let l = m.seq_len();
        let params = t5x::model::init_params(&m, 0);
        for &n in &[1usize, 4, 8] {
            let prompts: Vec<Vec<i32>> = (0..n)
                .map(|i| {
                    (0..plen).map(|j| ((5 + i * 7 + j * 3) % 400 + 2) as i32).collect()
                })
                .collect();
            let useful = (n * gen) as f64;
            let mut naive = InferEngine::with_mode(
                &arts, &device, model, &params, eos, Some(DecodeMode::Rescore),
            )
            .unwrap();
            bench.measure_with_throughput(
                &format!("{model} naive serial rescore ({n} reqs x {gen} tok)"),
                Some((useful, "tok")),
                || {
                    for p in &prompts {
                        naive
                            .submit(InferRequest {
                                id: 0,
                                prompt: p.clone(),
                                max_tokens: gen,
                                method: DecodeMethod::Greedy,
                            })
                            .unwrap();
                        let r = naive.run_until_idle().unwrap();
                        assert_eq!(r[0].tokens.len(), gen);
                    }
                },
            );
            let mut rescore = InferEngine::with_mode(
                &arts, &device, model, &params, eos, Some(DecodeMode::Rescore),
            )
            .unwrap();
            let rescore_tps = bench
                .measure_with_throughput(
                    &format!("{model} engine rescore ({n} reqs x {gen} tok)"),
                    Some((useful, "tok")),
                    || {
                        submit_all(&mut rescore, &prompts, gen);
                        let r = rescore.run_until_idle().unwrap();
                        assert_eq!(r.len(), n);
                    },
                )
                .throughput_per_sec()
                .unwrap();
            let mut kv = InferEngine::with_mode(
                &arts, &device, model, &params, eos, Some(DecodeMode::Kv),
            )
            .expect("kv mode needs prefill/decode_step (re-export artifacts)");
            let kv_tps = bench
                .measure_with_throughput(
                    &format!("{model} engine kv ({n} reqs x {gen} tok)"),
                    Some((useful, "tok")),
                    || {
                        submit_all(&mut kv, &prompts, gen);
                        let r = kv.run_until_idle().unwrap();
                        assert_eq!(r.len(), n);
                    },
                )
                .throughput_per_sec()
                .unwrap();
            let (rs, ks) = (rescore.summary(), kv.summary());
            println!(
                "  {model} n={n}: per-step decode {:.3} ms (rescore) vs {:.3} ms \
                 (kv steady-state; {} prefills/{} kv_steps), utilization {:.1}%, \
                 kv/rescore tokens/s = {:.2}x",
                rs.seconds_per_step * 1e3,
                ks.seconds_per_step * 1e3,
                ks.prefills,
                kv.counters().get("infer/kv_steps"),
                ks.slot_utilization * 100.0,
                kv_tps / rescore_tps.max(1e-12),
            );
            if l >= 128 {
                assert!(
                    kv_tps >= rescore_tps,
                    "{model} n={n}: kv tokens/s ({kv_tps:.1}) must be >= \
                     rescore ({rescore_tps:.1}) at L={l}"
                );
            }
            // §Obs: request-latency percentiles (accumulated over every
            // bench iteration) for the BENCH_<pr>.json serve-p99 section
            append_row(
                "bench_results.jsonl",
                &Json::obj(vec![
                    ("group", Json::str("serve latency (obs)")),
                    ("name", Json::str(format!("{model} kv ({n} reqs x {gen} tok)"))),
                    ("ttft_ms_p50", Json::num(ks.ttft_ms_p50)),
                    ("ttft_ms_p99", Json::num(ks.ttft_ms_p99)),
                    ("latency_ms_p50", Json::num(ks.latency_ms_p50)),
                    ("latency_ms_p99", Json::num(ks.latency_ms_p99)),
                ]),
            );
        }
    }
    bench.write_jsonl("bench_results.jsonl").unwrap();
    device.shutdown();
}
