//! Minimal SIGINT hook (stdlib-only, raw `signal(2)` FFI) so ctrl-C
//! triggers a graceful drain instead of killing mid-flight requests.
//!
//! The handler does the only async-signal-safe thing possible: one
//! relaxed atomic store. The serve loop polls [`sigint_triggered`] and
//! runs the ordinary drain path (stop admission → finish in-flight →
//! flush trace/metrics → print the summary). A second ctrl-C during the
//! drain falls back to the default disposition (immediate exit), so a
//! wedged drain can still be interrupted.

use std::sync::atomic::{AtomicBool, Ordering};

static SIGINT_FLAG: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
mod imp {
    use super::SIGINT_FLAG;
    use std::os::raw::c_int;
    use std::sync::atomic::Ordering;

    const SIGINT: c_int = 2;
    /// `SIG_DFL` — restore the default disposition from inside the
    /// handler so the *next* ctrl-C terminates immediately.
    const SIG_DFL: usize = 0;

    extern "C" {
        // Typing the handler as a fn pointer (not usize) keeps the
        // install below cast-free; libc's signature is compatible.
        fn signal(signum: c_int, handler: extern "C" fn(c_int)) -> usize;
        // Same libc symbol, usize-handler view for passing SIG_DFL.
        #[link_name = "signal"]
        fn signal_dfl(signum: c_int, handler: usize) -> usize;
    }

    extern "C" fn on_sigint(_sig: c_int) {
        SIGINT_FLAG.store(true, Ordering::Relaxed);
        // Re-arm to default: second ctrl-C exits without waiting.
        unsafe {
            signal_dfl(SIGINT, SIG_DFL);
        }
    }

    pub fn install() {
        unsafe {
            signal(SIGINT, on_sigint);
        }
    }
}

#[cfg(not(unix))]
mod imp {
    pub fn install() {}
}

/// Install the SIGINT → drain-flag handler (idempotent; no-op off Unix).
pub fn install_sigint() {
    imp::install();
}

/// True once SIGINT arrived (sticky until [`reset_sigint`]).
pub fn sigint_triggered() -> bool {
    SIGINT_FLAG.load(Ordering::Relaxed)
}

/// Clear the flag (tests, or re-entering a serve loop).
pub fn reset_sigint() {
    SIGINT_FLAG.store(false, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_round_trips() {
        reset_sigint();
        assert!(!sigint_triggered());
        SIGINT_FLAG.store(true, Ordering::Relaxed);
        assert!(sigint_triggered());
        reset_sigint();
        assert!(!sigint_triggered());
    }

    #[test]
    fn install_is_idempotent() {
        install_sigint();
        install_sigint();
    }
}
