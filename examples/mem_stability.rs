//! Memory-stability diagnostic: RSS must stay flat across hundreds of
//! train-step executions. This guards against the input-buffer leak we
//! found (and fixed) in the PJRT execute path — see
//! rust/src/runtime/service.rs and EXPERIMENTS.md §Perf.
//!
//! ```bash
//! cargo run --release --example mem_stability
//! ```

fn rss_kb() -> usize {
    std::fs::read_to_string("/proc/self/status")
        .unwrap()
        .lines()
        .find(|l| l.starts_with("VmRSS"))
        .unwrap()
        .split_whitespace()
        .nth(1)
        .unwrap()
        .parse()
        .unwrap()
}

fn main() -> anyhow::Result<()> {
    let arts = t5x::runtime::Artifacts::load_default()?;
    let m = arts.model("t5-nano-dec")?;
    let dev = t5x::runtime::DeviceHandle::spawn()?;
    let (exe, _) = dev.compile(&m.entrypoint("train_step")?.hlo)?;
    let params = t5x::model::pattern_params(m, 0);
    let mut inputs = t5x::model::params_in_order(m, &params);
    inputs.extend(t5x::model::golden::golden_batch(m));

    // warmup: allocator pools fill on the first batch of runs
    for _ in 0..100 {
        std::hint::black_box(exe.run(inputs.clone())?);
    }
    let baseline = rss_kb();
    println!("baseline after warmup: {baseline} kB");
    for round in 0..5 {
        for _ in 0..100 {
            std::hint::black_box(exe.run(inputs.clone())?);
        }
        let now = rss_kb();
        println!("after {} more runs: {now} kB (delta {})", (round + 1) * 100,
            now as i64 - baseline as i64);
    }
    let final_rss = rss_kb();
    assert!(
        final_rss < baseline + 20_000,
        "memory grew {} kB over 500 steps — leak regression!",
        final_rss - baseline
    );
    println!("mem_stability OK");
    dev.shutdown();
    Ok(())
}
