#!/usr/bin/env python3
"""Bench trajectory snapshot + regression gate (stdlib only).

Reads the ``bench_results.jsonl`` that ``cargo bench`` appends (one JSON
object per measurement, see ``rust/src/bench/mod.rs::write_jsonl``),
writes a compact ``BENCH_<pr>.json`` snapshot for the committed
``benchmarks/`` trajectory, and gates two headlines:

* **PR 6** — on any model-parallel mesh (model degree >= 2), block
  execution must not be slower than gather execution of the same
  (model, mesh, strategy) case (``--tolerance``).
* **PR 7** — an armed tracer must not slow the train step: each
  ``... traced (N steps)`` row must hold tok/s within
  ``--trace-tolerance`` of its untraced twin. The nominal contract is
  3%; quick-mode CI medians are noisy, so CI passes a looser value and
  the snapshot records the exact ratios either way.
* **PR 8** — the serving gateway must not tax throughput: under the
  open-loop Poisson workload (``serve gateway (poisson)``), 2-replica
  tok/s must hold the 1-replica line within ``--gateway-tolerance``.
  (One device thread serializes HLO executions, so the gate is
  "replicas are free", not "replicas are 2x".)

The snapshot also distills the PR-7 observability rows: the per-phase
step-time breakdown (``train phase breakdown (obs)``) and the serve
latency percentiles (``serve latency (obs)``).

Usage (CI smoke job):

    python tools/bench_gate.py --input rust/bench_results.jsonl \
        --output benchmarks/BENCH_8.json [--tolerance 0.10] \
        [--trace-tolerance 0.10] [--gateway-tolerance 0.10]

Exit status is non-zero if a gate fails or if the input contains no pair
to compare (so a silently-skipped comparison cannot read as a pass).
"""

import argparse
import json
import re
import sys

# "t5-nano-dec mesh=1x2 OneD block (2 steps)" — see bench_train_step.rs
TRAIN_ROW = re.compile(
    r"^(?P<model>\S+) mesh=(?P<data>\d+)x(?P<mdeg>\d+) "
    r"(?P<strategy>\w+) (?P<exec>gather|block) \(\d+ steps\)$"
)
# "t5-nano-dec mesh=1x2 OneD block traced (2 steps)"
TRACED_ROW = re.compile(
    r"^(?P<model>\S+) mesh=(?P<data>\d+)x(?P<mdeg>\d+) "
    r"(?P<strategy>\w+) (?P<exec>gather|block) traced \(\d+ steps\)$"
)
TRAIN_GROUP = "train step (E16)"
PHASE_GROUP = "train phase breakdown (obs)"
SERVE_GROUP = "serve latency (obs)"
GATEWAY_GROUP = "serve gateway (poisson)"


def load_rows(path):
    rows = []
    with open(path, "r", encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows


def gate_block(rows, tolerance):
    """Return (pairs, failures) for the block-vs-gather comparison."""
    cases = {}
    for r in rows:
        if r.get("group") != TRAIN_GROUP:
            continue
        m = TRAIN_ROW.match(r.get("name", ""))
        if not m or int(m.group("mdeg")) < 2:
            continue
        key = (m.group("model"), m.group("data"), m.group("mdeg"),
               m.group("strategy"))
        cases.setdefault(key, {})[m.group("exec")] = r.get("throughput_per_s")
    pairs, failures = [], []
    for key, by_exec in sorted(cases.items()):
        if "gather" not in by_exec or "block" not in by_exec:
            continue
        g, b = by_exec["gather"], by_exec["block"]
        pair = {
            "model": key[0],
            "mesh": f"{key[1]}x{key[2]}",
            "strategy": key[3],
            "gather_tok_per_s": g,
            "block_tok_per_s": b,
            "block_over_gather": (b / g) if g else None,
        }
        pairs.append(pair)
        if g and b < g * (1.0 - tolerance):
            failures.append(
                f"{pair['model']} mesh={pair['mesh']} {pair['strategy']}: "
                f"block {b:.1f} tok/s < gather {g:.1f} tok/s "
                f"(ratio {b / g:.3f}, tolerance {tolerance:.2f})"
            )
    return pairs, failures


def gate_tracing(rows, tolerance):
    """Return (pairs, failures) for the traced-vs-untraced comparison."""
    plain, traced = {}, {}
    for r in rows:
        if r.get("group") != TRAIN_GROUP:
            continue
        name = r.get("name", "")
        m = TRACED_ROW.match(name)
        if m:
            bucket = traced
        else:
            m = TRAIN_ROW.match(name)
            bucket = plain
        if not m:
            continue
        key = (m.group("model"), m.group("data"), m.group("mdeg"),
               m.group("strategy"), m.group("exec"))
        bucket[key] = r.get("throughput_per_s")
    pairs, failures = [], []
    for key in sorted(set(plain) & set(traced)):
        p, t = plain[key], traced[key]
        pair = {
            "model": key[0],
            "mesh": f"{key[1]}x{key[2]}",
            "strategy": key[3],
            "exec": key[4],
            "untraced_tok_per_s": p,
            "traced_tok_per_s": t,
            "traced_over_untraced": (t / p) if p else None,
        }
        pairs.append(pair)
        if p and t < p * (1.0 - tolerance):
            failures.append(
                f"{pair['model']} mesh={pair['mesh']} {pair['strategy']} "
                f"{pair['exec']}: traced {t:.1f} tok/s < untraced {p:.1f} "
                f"tok/s (ratio {t / p:.3f}, tolerance {tolerance:.2f})"
            )
    return pairs, failures


def gate_gateway(rows, tolerance):
    """Return (rows, failures) for the replica-scaling comparison."""
    by_replicas = {}
    gateway_rows = []
    for r in rows:
        if r.get("group") != GATEWAY_GROUP:
            continue
        gateway_rows.append({k: v for k, v in r.items() if k != "group"})
        n = r.get("replicas")
        if n is not None:
            by_replicas[int(n)] = r.get("tok_per_s")
    failures = []
    one, two = by_replicas.get(1), by_replicas.get(2)
    if one is None or two is None:
        return gateway_rows, None, failures
    ratio = (two / one) if one else None
    if one and two < one * (1.0 - tolerance):
        failures.append(
            f"gateway poisson: 2-replica {two:.1f} tok/s < 1-replica "
            f"{one:.1f} tok/s (ratio {ratio:.3f}, tolerance {tolerance:.2f})"
        )
    return gateway_rows, ratio, failures


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--input", required=True, help="bench_results.jsonl path")
    ap.add_argument("--output", required=True, help="BENCH_<pr>.json path")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed fractional block-vs-gather shortfall")
    ap.add_argument("--trace-tolerance", type=float, default=0.03,
                    help="allowed fractional traced-vs-untraced shortfall "
                         "(3%% nominal contract)")
    ap.add_argument("--gateway-tolerance", type=float, default=0.10,
                    help="allowed fractional 2-replica-vs-1-replica "
                         "gateway throughput shortfall")
    args = ap.parse_args()

    rows = load_rows(args.input)
    block_pairs, block_failures = gate_block(rows, args.tolerance)
    trace_pairs, trace_failures = gate_tracing(rows, args.trace_tolerance)
    gateway_rows, gateway_ratio, gateway_failures = gate_gateway(
        rows, args.gateway_tolerance)

    snapshot = {
        "schema": "t5x-bench-trajectory-v1",
        "source": args.input,
        "gate": {
            "rule": "block tok/s >= gather tok/s at model degree >= 2",
            "tolerance": args.tolerance,
            "pairs": block_pairs,
            "failures": block_failures,
        },
        "trace_gate": {
            "rule": "traced tok/s >= untraced tok/s per train-step case",
            "tolerance": args.trace_tolerance,
            "pairs": trace_pairs,
            "failures": trace_failures,
        },
        "gateway": {
            "rule": "2-replica poisson tok/s >= 1-replica tok/s",
            "tolerance": args.gateway_tolerance,
            "two_over_one": gateway_ratio,
            "rows": gateway_rows,
            "failures": gateway_failures,
        },
        "phase_breakdown": [
            {k: v for k, v in r.items() if k != "group"}
            for r in rows if r.get("group") == PHASE_GROUP
        ],
        "serve_latency": [
            {k: v for k, v in r.items() if k != "group"}
            for r in rows if r.get("group") == SERVE_GROUP
        ],
        "measurements": [
            {
                "group": r.get("group"),
                "name": r.get("name"),
                "median_s": r.get("median_s"),
                "throughput_per_s": r.get("throughput_per_s"),
                "throughput_unit": r.get("throughput_unit"),
            }
            for r in rows if "median_s" in r
        ],
    }
    with open(args.output, "w", encoding="utf-8") as f:
        json.dump(snapshot, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.output}: {len(rows)} rows, "
          f"{len(block_pairs)} gather-vs-block pair(s), "
          f"{len(trace_pairs)} traced-vs-untraced pair(s), "
          f"{len(gateway_rows)} gateway row(s)")

    status = 0
    if not block_pairs:
        print("gate: FAIL — no gather-vs-block pair found in "
              f"group '{TRAIN_GROUP}' (bench_train_step did not run?)",
              file=sys.stderr)
        status = 1
    if not trace_pairs:
        print("trace gate: FAIL — no traced-vs-untraced pair found in "
              f"group '{TRAIN_GROUP}' (bench_train_step did not run?)",
              file=sys.stderr)
        status = 1
    for f_ in block_failures:
        print(f"gate: FAIL — {f_}", file=sys.stderr)
        status = 1
    for f_ in trace_failures:
        print(f"trace gate: FAIL — {f_}", file=sys.stderr)
        status = 1
    if gateway_ratio is None:
        print("gateway gate: FAIL — no 1-vs-2 replica pair found in "
              f"group '{GATEWAY_GROUP}' (bench_decode did not run?)",
              file=sys.stderr)
        status = 1
    for f_ in gateway_failures:
        print(f"gateway gate: FAIL — {f_}", file=sys.stderr)
        status = 1
    if status:
        return status
    for p in block_pairs:
        print(f"gate: ok — {p['model']} mesh={p['mesh']} {p['strategy']} "
              f"block/gather = {p['block_over_gather']:.3f}")
    for p in trace_pairs:
        print(f"trace gate: ok — {p['model']} mesh={p['mesh']} "
              f"{p['strategy']} {p['exec']} traced/untraced = "
              f"{p['traced_over_untraced']:.3f}")
    print(f"gateway gate: ok — 2-replica/1-replica tok/s = "
          f"{gateway_ratio:.3f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
