//! Multi-engine replica router: N [`InferEngine`]s, one admission queue.
//!
//! [`Gateway::launch`] takes pre-built engine replicas (see
//! [`InferEngine::replica`] — clones share compiled executables and
//! Arc-backed parameter tensors, each gets private slots/KV cache) and
//! runs each on its own thread. Dispatch is **least-loaded by
//! construction**: a replica pulls at most `free_slots` requests from the
//! queue per step, so work flows to whichever replica has capacity and a
//! saturated replica cannot hoard the queue. There is no separate router
//! thread to become a bottleneck — the queue *is* the router.
//!
//! Timing is **client-true** at this layer: `latency_ms`/`ttft_ms`/
//! `queue_ms` on a [`ServeOutcome::Done`] start at gateway submit, so the
//! admission queue wait that the engine never sees is included (the
//! engine-internal numbers remain available on the embedded
//! [`InferResult`]).
//!
//! Each replica runs its engine steps inside `serve/replica<i>/step`
//! spans on a thread track named `serve/replica<i>`, with the engine's
//! own queue/slot trace events namespaced per replica via
//! [`InferEngine::set_trace_label`] — one trace shows every replica's
//! timeline side by side.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use super::admission::{AdmissionQueue, AdmitError, Pending, Popped};
use super::{OutcomeSender, ServeOutcome, ShedReason, SubmitOpts};
use crate::infer::{validate_request, EngineSummary, InferEngine, InferRequest};
use crate::metrics::CounterSet;
use crate::obs::Histogram;
use crate::runtime::artifacts::ModelManifest;
use crate::util::json::Json;

/// Gateway tuning knobs (`serve.queue_depth` / `serve.shed_watermark` in
/// gin, `--queue-depth` / `--shed-watermark` on the CLI).
#[derive(Debug, Clone)]
pub struct GatewayConfig {
    /// Admission queue capacity (submits past it get 429).
    pub queue_depth: usize,
    /// Depth at which `priority <= 0` work is shed; `None` disables
    /// (watermark = capacity), so plain batch workloads see no shedding.
    pub shed_watermark: Option<usize>,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig { queue_depth: 64, shed_watermark: None }
    }
}

/// Live, shared view of one replica's engine stats (histograms and
/// counters share storage with the engine via Arc-backed clones, so
/// `/metrics` reads them while the replica thread steps).
struct ReplicaStats {
    batch: usize,
    counters: CounterSet,
    ttft: Histogram,
    latency: Histogram,
    queue: Histogram,
    /// Cleared when the replica thread dies (step error or panic); the
    /// shared admission queue then routes around the corpse and
    /// `/healthz` reports `degraded`.
    alive: Arc<AtomicBool>,
}

/// Final shutdown report: per-replica engine summaries plus the
/// gateway-level (client-true) aggregates.
#[derive(Debug, Clone)]
pub struct GatewayReport {
    pub replicas: Vec<EngineSummary>,
    pub completed: u64,
    pub tokens: u64,
    pub wall_seconds: f64,
    pub tokens_per_sec: f64,
    /// Client-true percentiles (gateway submit → event), ms.
    pub queue_ms_p50: f64,
    pub queue_ms_p99: f64,
    pub ttft_ms_p50: f64,
    pub ttft_ms_p99: f64,
    pub latency_ms_p50: f64,
    pub latency_ms_p99: f64,
    /// Gateway counter snapshot (`serve/*`).
    pub counters: Vec<(String, u64)>,
}

/// One admission queue feeding N engine replica threads; the single
/// scheduling path shared by the HTTP front end and the JSONL loop.
pub struct Gateway {
    queue: AdmissionQueue,
    counters: CounterSet,
    manifest: Option<ModelManifest>,
    stats: Vec<ReplicaStats>,
    /// Client-true (gateway submit → event) histograms, ms.
    ttft: Histogram,
    latency: Histogram,
    queue_total: Histogram,
    handles: Mutex<Vec<JoinHandle<anyhow::Result<EngineSummary>>>>,
    started: Instant,
}

/// Routing bookkeeping for an in-flight request: keyed by the
/// gateway-internal id the engine decodes under.
struct InFlight {
    client_id: u64,
    submitted: Instant,
    reply: OutcomeSender,
}

impl Gateway {
    /// Spawn one stepping thread per engine and return the shared
    /// gateway handle. An empty `engines` vec is allowed (admission-only
    /// mode, used by tests — queued work is flushed as shed on
    /// [`Gateway::shutdown`]).
    pub fn launch(engines: Vec<InferEngine>, cfg: GatewayConfig) -> Arc<Gateway> {
        let counters = CounterSet::new();
        let watermark = cfg.shed_watermark.unwrap_or(cfg.queue_depth);
        let queue =
            AdmissionQueue::new(cfg.queue_depth, watermark, counters.clone());
        let manifest = engines.first().map(|e| e.manifest.clone());
        let stats = engines
            .iter()
            .map(|e| ReplicaStats {
                batch: e.manifest.batch(),
                counters: e.counters().clone(),
                ttft: e.ttft_histogram().clone(),
                latency: e.latency_histogram().clone(),
                queue: e.queue_histogram().clone(),
                alive: Arc::new(AtomicBool::new(true)),
            })
            .collect();
        let gw = Arc::new(Gateway {
            queue,
            counters,
            manifest,
            stats,
            ttft: Histogram::new(),
            latency: Histogram::new(),
            queue_total: Histogram::new(),
            handles: Mutex::new(Vec::new()),
            started: Instant::now(),
        });
        let mut handles = Vec::new();
        for (i, engine) in engines.into_iter().enumerate() {
            let gwc = gw.clone();
            let h = std::thread::Builder::new()
                .name(format!("serve-replica{i}"))
                .spawn(move || replica_loop(gwc, engine, i))
                .expect("spawn replica thread");
            handles.push(h);
        }
        *gw.handles.lock().unwrap() = handles;
        gw
    }

    pub fn replicas(&self) -> usize {
        self.stats.len()
    }

    /// Replicas whose stepping threads are still running.
    pub fn alive_replicas(&self) -> usize {
        self.stats.iter().filter(|s| s.alive.load(Ordering::SeqCst)).count()
    }

    /// Mark a replica dead, account for work that must find a new home,
    /// and — when it was the last one — close admission so clients get a
    /// fast rejection instead of queueing into the void.
    fn mark_replica_dead(&self, idx: usize) {
        self.stats[idx].alive.store(false, Ordering::SeqCst);
        self.counters.inc("serve/replica_failures");
        // Everything still queued at the instant of death will be pulled
        // by a surviving replica (the queue *is* the router).
        self.counters.add("serve/rerouted_queued", self.queue.depth() as u64);
        if self.alive_replicas() == 0 {
            eprintln!("serve: last replica died; closing admission");
            self.queue.close();
        }
    }

    /// True once [`Gateway::drain`]/[`Gateway::shutdown`] stopped
    /// admission.
    pub fn draining(&self) -> bool {
        !self.queue.is_open()
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }

    pub fn counters(&self) -> &CounterSet {
        &self.counters
    }

    /// Validate and enqueue a request; exactly one [`ServeOutcome`] will
    /// arrive on `reply` if this returns `Ok`. The request's `id` is the
    /// client's and is echoed back; internally the gateway re-keys it so
    /// concurrent clients may reuse ids freely.
    pub fn submit(
        &self,
        mut req: InferRequest,
        opts: SubmitOpts,
        reply: OutcomeSender,
    ) -> Result<(), AdmitError> {
        if let Some(m) = &self.manifest {
            validate_request(m, &req).map_err(|e| {
                self.counters.inc("serve/rejected_invalid");
                AdmitError::Invalid(e.to_string())
            })?;
        }
        let client_id = req.id;
        req.id = self.queue.next_internal_id();
        self.counters.inc("serve/submitted");
        self.queue.submit(Pending {
            req,
            opts,
            client_id,
            submitted: Instant::now(),
            reply,
        })
    }

    /// Stop admission; replicas finish the queue and in-flight slots,
    /// then exit. Call [`Gateway::shutdown`] to join them.
    pub fn drain(&self) {
        self.queue.close();
    }

    /// Drain, join every replica thread, flush anything still queued as
    /// [`ServeOutcome::Shed`] (possible only with zero live replicas),
    /// and return the final report.
    pub fn shutdown(&self) -> GatewayReport {
        self.queue.close();
        let handles = std::mem::take(&mut *self.handles.lock().unwrap());
        let mut replicas = Vec::new();
        for h in handles {
            match h.join() {
                Ok(Ok(summary)) => replicas.push(summary),
                Ok(Err(e)) => {
                    self.counters.inc("serve/replica_errors");
                    eprintln!("serve: replica thread failed: {e:#}");
                }
                Err(_) => {
                    self.counters.inc("serve/replica_errors");
                    eprintln!("serve: replica thread panicked");
                }
            }
        }
        for p in self.queue.drain_remaining() {
            self.counters.inc("serve/shed_draining");
            let waited_ms = p.submitted.elapsed().as_secs_f64() * 1e3;
            let _ = p.reply.send(ServeOutcome::Shed {
                client_id: p.client_id,
                reason: ShedReason::Draining,
                waited_ms,
            });
        }
        let tokens = self.counters.get("serve/tokens");
        let wall = self.started.elapsed().as_secs_f64();
        GatewayReport {
            replicas,
            completed: self.counters.get("serve/completed"),
            tokens,
            wall_seconds: wall,
            tokens_per_sec: if wall > 0.0 { tokens as f64 / wall } else { 0.0 },
            queue_ms_p50: self.queue_total.p50(),
            queue_ms_p99: self.queue_total.p99(),
            ttft_ms_p50: self.ttft.p50(),
            ttft_ms_p99: self.ttft.p99(),
            latency_ms_p50: self.latency.p50(),
            latency_ms_p99: self.latency.p99(),
            counters: self.counters.snapshot(),
        }
    }

    fn hist_json(h: &Histogram) -> Json {
        Json::obj(vec![
            ("p50", Json::num(h.p50())),
            ("p95", Json::num(h.p95())),
            ("p99", Json::num(h.p99())),
            ("mean_ms", Json::num(h.mean_ms())),
            ("count", Json::num(h.count() as f64)),
        ])
    }

    /// The `GET /metrics` document: gateway counters, client-true
    /// histogram percentiles, queue state, and per-replica utilization.
    pub fn metrics_json(&self) -> Json {
        let counters = Json::Obj(
            self.counters
                .snapshot()
                .into_iter()
                .map(|(k, v)| (k, Json::num(v as f64)))
                .collect(),
        );
        let replicas: Vec<Json> = self
            .stats
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let steps = s.counters.get("infer/steps");
                let busy = s.counters.get("infer/slot_steps_busy");
                let util = if steps > 0 {
                    busy as f64 / (steps * s.batch as u64) as f64
                } else {
                    0.0
                };
                Json::obj(vec![
                    ("replica", Json::num(i as f64)),
                    (
                        "state",
                        Json::str(if s.alive.load(Ordering::SeqCst) { "up" } else { "down" }),
                    ),
                    ("completed", Json::num(s.counters.get("infer/requests_completed") as f64)),
                    ("tokens", Json::num(s.counters.get("infer/tokens") as f64)),
                    ("steps", Json::num(steps as f64)),
                    ("slot_utilization", Json::num(util)),
                    ("ttft_ms_p50", Json::num(s.ttft.p50())),
                    ("ttft_ms_p99", Json::num(s.ttft.p99())),
                    ("latency_ms_p99", Json::num(s.latency.p99())),
                    ("queue_ms_p99", Json::num(s.queue.p99())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("counters", counters),
            (
                "histograms_ms",
                Json::obj(vec![
                    ("queue_wait", Self::hist_json(self.queue.queue_wait())),
                    ("queue_total", Self::hist_json(&self.queue_total)),
                    ("ttft", Self::hist_json(&self.ttft)),
                    ("latency", Self::hist_json(&self.latency)),
                ]),
            ),
            (
                "queue",
                Json::obj(vec![
                    ("depth", Json::num(self.queue.depth() as f64)),
                    ("capacity", Json::num(self.queue.capacity() as f64)),
                    ("watermark", Json::num(self.queue.watermark() as f64)),
                    ("draining", Json::Bool(self.draining())),
                ]),
            ),
            ("replicas", Json::Arr(replicas)),
        ])
    }

    /// The `GET /healthz` document. A gateway that has lost replicas but
    /// still has survivors reports `degraded`; one that has lost *all* of
    /// them reports `down`. `per_replica` names each replica `up`/`down`
    /// so an operator can see which host to recycle.
    pub fn healthz_json(&self) -> Json {
        let total = self.replicas();
        let alive = self.alive_replicas();
        let status = if total > 0 && alive == 0 {
            "down"
        } else if total > 0 && alive < total {
            "degraded"
        } else if self.draining() {
            "draining"
        } else {
            "ok"
        };
        let per_replica: Vec<Json> = self
            .stats
            .iter()
            .enumerate()
            .map(|(i, s)| {
                Json::obj(vec![
                    ("replica", Json::num(i as f64)),
                    (
                        "state",
                        Json::str(if s.alive.load(Ordering::SeqCst) { "up" } else { "down" }),
                    ),
                ])
            })
            .collect();
        Json::obj(vec![
            ("status", Json::str(status)),
            ("replicas", Json::num(total as f64)),
            ("replicas_alive", Json::num(alive as f64)),
            ("queue_depth", Json::num(self.queue.depth() as f64)),
            ("per_replica", Json::Arr(per_replica)),
        ])
    }
}

/// One replica's supervised stepping loop: runs [`replica_work`] under
/// `catch_unwind` so a panicking replica (a poisoned engine, an injected
/// `replica_panic` fault) dies *cleanly* — every in-flight request is
/// answered with [`ServeOutcome::Failed`], the replica is marked dead for
/// `/healthz`, and the shared admission queue keeps feeding the
/// survivors.
///
/// The in-flight map lives in a [`Mutex`] owned by this frame (not by
/// `replica_work`) precisely so it survives the unwind and can be
/// flushed.
fn replica_loop(
    gw: Arc<Gateway>,
    engine: InferEngine,
    idx: usize,
) -> anyhow::Result<EngineSummary> {
    let inflight: Mutex<HashMap<u64, InFlight>> = Mutex::new(HashMap::new());
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        replica_work(&gw, engine, idx, &inflight)
    }));
    let err = match result {
        Ok(Ok(summary)) => return Ok(summary),
        Ok(Err(e)) => e,
        Err(payload) => {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "replica panicked".to_string());
            anyhow::anyhow!("replica {idx} panicked: {msg}")
        }
    };
    gw.mark_replica_dead(idx);
    // Clients blocked on recv must hear about the failure or they hang
    // forever; flush every request this replica had accepted.
    let msg = format!("replica {idx} died: {err:#}");
    eprintln!("serve: {msg}");
    let drained = std::mem::take(
        &mut *inflight.lock().unwrap_or_else(|poison| poison.into_inner()),
    );
    for (_, m) in drained {
        gw.counters.inc("serve/failed");
        gw.counters.inc("serve/failed_inflight");
        let _ = m.reply.send(ServeOutcome::Failed {
            client_id: m.client_id,
            error: msg.clone(),
        });
    }
    Err(err)
}

/// The actual pull/step/route loop: pull up to `free_slots` requests,
/// step the engine, route completions back. Exits when the queue closes
/// and all local work is done. Errors and panics are handled by
/// [`replica_loop`].
fn replica_work(
    gw: &Gateway,
    mut engine: InferEngine,
    idx: usize,
    inflight: &Mutex<HashMap<u64, InFlight>>,
) -> anyhow::Result<EngineSummary> {
    let tracer = engine.tracer().clone();
    tracer.name_track(format!("serve/replica{idx}"));
    let step_span = format!("serve/replica{idx}/step");
    let batch = engine.manifest.batch();
    loop {
        let free = batch.saturating_sub(engine.active() + engine.queued());
        let mut closed = false;
        match gw.queue.pop(free, !engine.has_work()) {
            Popped::Closed => closed = true,
            Popped::Batch(batch_in) => {
                for p in batch_in {
                    let Pending { req, client_id, submitted, reply, .. } = p;
                    let internal_id = req.id;
                    // Record the request *before* anything can fail so a
                    // panic between here and engine acceptance still
                    // answers the client (via the flush in
                    // `replica_loop`).
                    inflight.lock().unwrap().insert(
                        internal_id,
                        InFlight { client_id, submitted, reply },
                    );
                    if crate::faults::replica_panic(idx, client_id) {
                        panic!(
                            "fault injected: replica_panic(replica={idx}, \
                             request={client_id})"
                        );
                    }
                    if let Err(e) = engine.submit(req) {
                        // validate_request should have caught this at
                        // submit; engines can still reject (e.g. a
                        // manifest-less test gateway).
                        if let Some(m) = inflight.lock().unwrap().remove(&internal_id)
                        {
                            gw.counters.inc("serve/failed");
                            let _ = m.reply.send(ServeOutcome::Failed {
                                client_id: m.client_id,
                                error: format!("{e:#}"),
                            });
                        }
                    }
                }
            }
        }
        if engine.has_work() {
            {
                let _sp = tracer.span(&step_span);
                engine.step()?;
            }
            for r in engine.drain_finished() {
                let Some(m) = inflight.lock().unwrap().remove(&r.id) else {
                    continue; // unreachable: every submit records an entry
                };
                let latency_s = m.submitted.elapsed().as_secs_f64();
                // Gateway wait = client-true latency minus the engine's
                // own submit-to-completion clock.
                let gw_wait_s = (latency_s - r.latency_seconds).max(0.0);
                let queue_s = gw_wait_s + r.queue_seconds;
                let ttft_s = r.ttft_seconds.map(|t| gw_wait_s + t);
                gw.latency.record_seconds(latency_s);
                gw.queue_total.record_seconds(queue_s);
                if let Some(t) = ttft_s {
                    gw.ttft.record_seconds(t);
                }
                gw.counters.inc("serve/completed");
                gw.counters.add("serve/tokens", r.tokens.len() as u64);
                gw.counters.inc(&format!("serve/replica{idx}/completed"));
                let _ = m.reply.send(ServeOutcome::Done {
                    client_id: m.client_id,
                    result: r,
                    replica: idx,
                    queue_ms: queue_s * 1e3,
                    ttft_ms: ttft_s.map(|t| t * 1e3),
                    latency_ms: latency_s * 1e3,
                });
            }
        } else if closed {
            break;
        }
    }
    Ok(engine.summary())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::DecodeMethod;
    use std::sync::mpsc;
    use std::time::Duration;

    fn req(id: u64) -> InferRequest {
        InferRequest {
            id,
            prompt: vec![5, 9],
            max_tokens: 4,
            method: DecodeMethod::Greedy,
        }
    }

    // Admission semantics are fully testable with zero replicas: the
    // queue accepts/rejects, and shutdown sheds whatever is left.
    #[test]
    fn admission_only_gateway_backpressure_and_shed() {
        let gw = Gateway::launch(
            Vec::new(),
            GatewayConfig { queue_depth: 2, shed_watermark: Some(1) },
        );
        let (tx, rx) = mpsc::channel();
        gw.submit(req(1), SubmitOpts { priority: 1, deadline: None }, tx.clone())
            .unwrap();
        // depth 1 == watermark: default priority is shed early...
        match gw.submit(req(2), SubmitOpts::default(), tx.clone()) {
            Err(AdmitError::ShedLowPriority { .. }) => {}
            other => panic!("expected watermark shed, got {other:?}"),
        }
        // ...high priority still admitted until capacity...
        gw.submit(req(3), SubmitOpts { priority: 5, deadline: None }, tx.clone())
            .unwrap();
        // ...and past capacity everyone gets backpressure.
        match gw.submit(req(4), SubmitOpts { priority: 9, deadline: None }, tx.clone()) {
            Err(AdmitError::QueueFull { depth: 2, .. }) => {}
            other => panic!("expected queue full, got {other:?}"),
        }
        assert_eq!(gw.queue_depth(), 2);
        let report = gw.shutdown();
        // No replicas: both admitted requests flush as draining sheds.
        drop(tx);
        let mut shed = 0;
        while let Ok(o) = rx.try_recv() {
            match o {
                ServeOutcome::Shed { reason: ShedReason::Draining, .. } => shed += 1,
                other => panic!("expected draining shed, got {other:?}"),
            }
        }
        assert_eq!(shed, 2);
        assert_eq!(report.completed, 0);
        assert_eq!(gw.counters().get("serve/shed_draining"), 2);
        assert_eq!(gw.counters().get("serve/rejected_full"), 1);
        assert_eq!(gw.counters().get("serve/shed_lowpri"), 1);
    }

    #[test]
    fn submit_after_drain_is_rejected() {
        let gw = Gateway::launch(Vec::new(), GatewayConfig::default());
        gw.drain();
        assert!(gw.draining());
        let (tx, _rx) = mpsc::channel();
        assert_eq!(
            gw.submit(req(1), SubmitOpts::default(), tx),
            Err(AdmitError::Draining)
        );
        gw.shutdown();
    }

    #[test]
    fn metrics_and_healthz_render_without_replicas() {
        let gw = Gateway::launch(
            Vec::new(),
            GatewayConfig { queue_depth: 4, shed_watermark: None },
        );
        let (tx, _rx) = mpsc::channel();
        gw.submit(
            req(1),
            SubmitOpts { priority: 0, deadline: Some(Duration::from_secs(5)) },
            tx,
        )
        .unwrap();
        let m = gw.metrics_json();
        assert_eq!(m.get("queue").unwrap().get("depth").unwrap().as_f64(), Some(1.0));
        assert_eq!(
            m.get("queue").unwrap().get("capacity").unwrap().as_f64(),
            Some(4.0)
        );
        assert_eq!(
            m.get("counters").unwrap().get("serve/submitted").unwrap().as_f64(),
            Some(1.0)
        );
        let h = gw.healthz_json();
        assert_eq!(h.get("status").unwrap().as_str(), Some("ok"));
        gw.shutdown();
        assert_eq!(gw.healthz_json().get("status").unwrap().as_str(), Some("draining"));
    }
}
