//! Quickstart (E1): the whole Figure-1 stack in ~60 lines of user code.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//! Loads the AOT artifacts, trains the nano decoder for 30 steps on the
//! synthetic corpus through a deterministic seqio pipeline, evaluates, and
//! prints the loss curve — all from Rust, no Python on the hot path.

use t5x::optim::{OptimizerKind, Schedule};
use t5x::partitioning::ParamStrategy;
use t5x::runtime::{Artifacts, DeviceHandle};
use t5x::trainer::recipes;
use t5x::trainer::{BatchSource, Trainer, TrainerConfig};

fn main() -> anyhow::Result<()> {
    let arts = Artifacts::load_default()?;
    let device = DeviceHandle::spawn()?;
    let model = "t5-nano-dec";
    let m = arts.model(model)?;
    println!(
        "model {model}: {} params, batch {} x seq {}",
        m.total_params(),
        m.batch(),
        m.seq_len()
    );

    // 1. seqio: task -> deterministic cache (idempotent)
    let cache_dir = std::env::temp_dir().join("t5x_quickstart_cache");
    let task = recipes::lm_task("quickstart_lm", 400, m.seq_len(), 42);
    let meta = recipes::ensure_cached(&task, &cache_dir, 8, 0)?;
    println!("cached {} examples in {} shards", meta.num_examples, meta.num_shards);

    // 2. t5x: two data-parallel hosts, ZeRO-3 sharded optimizer
    let cfg = TrainerConfig {
        model: model.into(),
        num_hosts: 2,
        strategy: ParamStrategy::TwoD,
        optimizer: OptimizerKind::adam(),
        schedule: Schedule::RsqrtWithWarmup { peak: 3e-3, warmup: 10 },
        steps: 30,
        seed: 0,
        log_every: 5,
        checkpoint_every: None,
        checkpoint_dir: None,
        grad_clip_norm: None,
        weight_decay: None,
    };
    let trainer = Trainer::new(&arts, &device, cfg)?
        .with_logger(t5x::metrics::MetricsLogger::new().with_terminal());
    let infeed = recipes::cached_infeed(m, &cache_dir, 2, 0, None)?;
    let summary = trainer.train(&BatchSource::Infeed(infeed))?;
    println!(
        "\nloss {:.3} -> {:.3} over {} steps ({:.1}s, {} comm bytes)",
        summary.first_loss(),
        summary.final_loss(),
        summary.history.len(),
        summary.wall_seconds,
        summary.comm_bytes,
    );

    // 3. eval on held-out synthetic data
    let eval_task = recipes::lm_task("quickstart_eval", 50, m.seq_len(), 1234);
    let runner = t5x::trainer::eval::EvalRunner::new(&arts, &device, model)?;
    let metrics = runner.evaluate(
        &trainer.params(),
        recipes::eval_batches(m, &eval_task, 7, 4).into_iter(),
    )?;
    println!(
        "eval: loss {:.3}, token accuracy {:.1}% over {} batches",
        metrics.loss,
        metrics.accuracy * 100.0,
        metrics.num_batches
    );

    assert!(summary.final_loss() < summary.first_loss());
    println!("quickstart OK");
    device.shutdown();
    Ok(())
}
