//! E9: data-pipeline / infeed throughput — the §3.2 claim that
//! index-modulo file sharding + exclusive sequential reads + prefetch
//! "increase throughput and greatly reduce the chance of an input
//! bottleneck".
//!
//! Rows: (a) naive single shared reader fanning examples to hosts,
//! (b) per-host exclusive sharded readers, (c) sharded + threaded
//! prefetch + batch assembly (the production path), (d) order-preserving
//! `parallel_map` scaling on a tokenize-heavy preprocessor (1/2/4
//! workers vs serial map — tf.data `num_parallel_calls` semantics).

use std::sync::Arc;

use t5x::bench::Bench;
use t5x::runtime::Artifacts;
use t5x::seqio::dataset::Dataset;
use t5x::seqio::deterministic::{strip_index, DeterministicPipeline};
use t5x::seqio::feature_converters::{lengths, FeatureConverter, LmConverter};
use t5x::seqio::source::{DataSource, SyntheticTextSource};
use t5x::seqio::vocab::{ByteVocabulary, Vocabulary};
use t5x::seqio::{Example, Feature};
use t5x::trainer::recipes;

fn main() {
    let arts = Artifacts::load_default().expect("make artifacts first");
    let m = arts.model("t5-nano-dec").unwrap();
    let mut bench = Bench::new("infeed (E9)");
    let docs = if bench.is_quick() { 200 } else { 2000 };
    let hosts = 4;

    let root = std::env::temp_dir().join(format!("bench_infeed_{docs}"));
    let task = recipes::lm_task("bench_infeed_lm", docs, m.seq_len(), 42);
    let meta = recipes::ensure_cached(&task, &root, 16, 0).unwrap();
    // ensure_cached writes the per-split layout; this bench reads the
    // train split's directory directly
    let dir = if meta.splits.is_some() {
        t5x::seqio::cache::CacheMeta::split_dir(&root, "train")
    } else {
        root.clone()
    };
    let n = t5x::seqio::cache::CacheMeta::load(&dir).unwrap().num_examples;
    let per_host = n / hosts;

    // (a) naive: one global reader, examples dealt round-robin to hosts
    bench.measure_with_throughput(
        "naive shared reader -> 4 hosts",
        Some((n as f64, "ex")),
        || {
            let p = DeterministicPipeline::open(&dir).unwrap();
            let mut buckets: Vec<Vec<_>> = (0..hosts).map(|_| Vec::new()).collect();
            for (i, ex) in p.global_stream().enumerate() {
                buckets[i % hosts].push(ex);
            }
            std::hint::black_box(&buckets);
        },
    );

    // (b) sharded: per-host exclusive file sets, sequential reads
    bench.measure_with_throughput(
        "sharded exclusive readers (4 threads)",
        Some((n as f64, "ex")),
        || {
            let outs = t5x::collectives::run_ranks(hosts, |h| {
                let p = DeterministicPipeline::open(&dir).unwrap();
                p.host_stream(h, hosts, 0, false).collect_vec().len()
            });
            assert_eq!(outs.iter().sum::<usize>(), n);
        },
    );

    // (c) production: sharded + prefetch + converter + batch assembly
    let batch = m.batch();
    let batches_per_host = per_host / batch;
    bench.measure_with_throughput(
        "sharded + prefetch + convert + assemble",
        Some(((batches_per_host * batch * hosts) as f64, "ex")),
        || {
            let infeed = t5x::trainer::infeed::Infeed::spawn(m, hosts, 8, |host| {
                let p = DeterministicPipeline::open(&dir).unwrap();
                let tl = lengths(&[("targets", m.seq_len())]);
                let ds: Dataset =
                    p.host_stream(host, hosts, 0, false).map(strip_index);
                LmConverter.convert(ds, &tl)
            });
            let counts = t5x::collectives::run_ranks(hosts, |h| {
                let mut c = 0;
                while let Some(b) = infeed.next(h) {
                    std::hint::black_box(&b);
                    c += 1;
                }
                c
            });
            assert!(counts.iter().sum::<usize>() >= batches_per_host * hosts - hosts);
        },
    );

    // (d) parallel_map scaling: tokenize-heavy preprocessor, serial map vs
    // 1/2/4 workers. Output order is identical in all rows (asserted).
    let pdocs = if bench.is_quick() { 100 } else { 400 };
    let source = Arc::new(SyntheticTextSource::with_shape(7, pdocs, 8, 12));
    let vocab: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(16));
    let heavy = move |mut ex: Example| {
        if let Some(Feature::Text(t)) = ex.get("text") {
            // repeated tokenize/detokenize: a deliberately hot pure map
            let mut ids = vocab.encode(t);
            for _ in 0..16 {
                let txt = vocab.decode(&ids);
                ids = vocab.encode(&txt);
            }
            ex.insert("targets".into(), Feature::Ints(ids));
        }
        ex
    };
    let serial_out = source.all().map(heavy.clone()).collect_vec();
    bench.measure_with_throughput(
        "tokenize-heavy serial map",
        Some((pdocs as f64, "ex")),
        || {
            let out = source.all().map(heavy.clone()).collect_vec();
            assert_eq!(out.len(), pdocs);
            std::hint::black_box(&out);
        },
    );
    for workers in [1usize, 2, 4] {
        // order check once, outside the timed closure (it would bias the
        // scaling numbers); determinism is also covered by the tests
        let once = source.all().parallel_map(heavy.clone(), workers).collect_vec();
        assert_eq!(once, serial_out, "parallel_map must preserve order");
        bench.measure_with_throughput(
            &format!("tokenize-heavy parallel_map({workers})"),
            Some((pdocs as f64, "ex")),
            || {
                let out = source.all().parallel_map(heavy.clone(), workers).collect_vec();
                assert_eq!(out.len(), pdocs);
                std::hint::black_box(&out);
            },
        );
    }

    bench.write_jsonl("bench_results.jsonl").unwrap();
}
