//! Recipes: canonical task/pipeline constructions shared by the examples,
//! the CLI launcher, and the benches — the t5x "configs" directory as code.

use std::path::Path;
use std::sync::Arc;

use crate::runtime::artifacts::ModelManifest;
use crate::seqio::cache::{cache_task, CacheConfig, CacheMeta};
use crate::seqio::dataset::{Dataset, PipelineState};
use crate::seqio::deterministic::{strip_index, DeterministicPipeline};
use crate::seqio::feature_converters::{
    lengths, EncDecConverter, FeatureConverter, LmConverter,
};
use crate::seqio::preprocessors::{AppendEos, ChunkTokens, SpanCorruption, Tokenize};
use crate::seqio::source::SyntheticTextSource;
use crate::seqio::task::Task;
use crate::seqio::vocab::{ByteVocabulary, Vocabulary};
use crate::trainer::infeed::Infeed;

/// Byte vocabulary sized for every exported model (vocab >= 275).
pub fn default_vocab() -> Arc<dyn Vocabulary> {
    Arc::new(ByteVocabulary::new(16))
}

/// Causal-LM pretraining task over the synthetic corpus: tokenize ->
/// chunk(seq_len-1) -> append EOS. (The C4-substitute pipeline.)
pub fn lm_task(name: &str, docs: usize, seq_len: usize, seed: u64) -> Arc<Task> {
    let vocab = default_vocab();
    Task::builder(name)
        .source(Arc::new(SyntheticTextSource::new(seed, docs)))
        .preprocessor(Arc::new(Tokenize::new(vocab.clone(), &[("text", "targets")])))
        .preprocessor(Arc::new(ChunkTokens::new("targets", seq_len - 1)))
        .preprocessor(Arc::new(AppendEos::new(&["targets"])))
        .output_feature("targets", vocab, true)
        .build()
}

/// T5 span-corruption pretraining task (the enc-dec objective).
pub fn span_corruption_task(name: &str, docs: usize, seq_len: usize, seed: u64) -> Arc<Task> {
    let vocab = default_vocab();
    Task::builder(name)
        .source(Arc::new(SyntheticTextSource::new(seed, docs)))
        .preprocessor(Arc::new(Tokenize::new(vocab.clone(), &[("text", "targets")])))
        .preprocessor(Arc::new(ChunkTokens::new("targets", seq_len)))
        .preprocessor(Arc::new(SpanCorruption::new(vocab.clone())))
        .preprocessor(Arc::new(AppendEos::new(&["targets"])))
        .output_feature("inputs", vocab.clone(), false)
        .output_feature("targets", vocab, true)
        .build()
}

/// A synthetic *seq2seq* task with learnable structure: the target is the
/// input sentence with its words reversed. Used by the finetune/eval
/// example (E15) — exact-match/BLEU rise above chance quickly.
pub fn reverse_words_task(name: &str, examples: usize, seed: u64) -> Arc<Task> {
    let vocab = default_vocab();
    let src = SyntheticTextSource::with_shape(seed, examples, 1, 5);
    Task::builder(name)
        .source(Arc::new(src))
        .preprocessor(Arc::new(MapReverse))
        .preprocessor(Arc::new(Tokenize::new(
            vocab.clone(),
            &[("inputs_text", "inputs"), ("targets_text", "targets")],
        )))
        .preprocessor(Arc::new(AppendEos::new(&["targets"])))
        .output_feature("inputs", vocab.clone(), false)
        .output_feature("targets", vocab, true)
        .metric(crate::seqio::evaluation::Metric::ExactMatch)
        .metric(crate::seqio::evaluation::Metric::TokenAccuracy)
        .metric(crate::seqio::evaluation::Metric::Bleu)
        .build()
}

/// text -> (inputs_text = text, targets_text = words reversed).
struct MapReverse;

impl crate::seqio::preprocessors::Preprocessor for MapReverse {
    fn name(&self) -> &'static str {
        "map_reverse"
    }

    fn apply(
        &self,
        ds: Dataset,
        _ctx: &crate::seqio::preprocessors::PipelineCtx,
    ) -> Dataset {
        ds.map(|mut ex| {
            let text = ex["text"].as_text().unwrap_or("").trim_end_matches('.').to_string();
            let reversed: Vec<&str> = text.split_whitespace().rev().collect();
            ex.insert(
                "inputs_text".into(),
                crate::seqio::Feature::Text(text.clone()),
            );
            ex.insert(
                "targets_text".into(),
                crate::seqio::Feature::Text(reversed.join(" ")),
            );
            ex
        })
    }
}

/// Cache a task if not already cached (idempotent `make`-style).
pub fn ensure_cached(
    task: &Task,
    dir: &Path,
    num_shards: usize,
    seed: u64,
) -> anyhow::Result<CacheMeta> {
    if dir.join("cache_meta.json").exists() {
        let meta = CacheMeta::load(dir)?;
        if meta.num_shards == num_shards && meta.seed == seed {
            return Ok(meta);
        }
    }
    cache_task(task, dir, &CacheConfig { num_shards, seed, workers: 4 })
}

/// Infeed over a cached deterministic pipeline with the right converter
/// for the model arch. Positioning: when `resume` carries checkpointed
/// per-host pipeline states they win (exact op-graph restore); otherwise
/// the stream starts at `start_step * batch` (the coarse positional
/// fallback for checkpoints that predate pipeline state).
pub fn cached_infeed(
    m: &ModelManifest,
    cache_dir: &Path,
    num_hosts: usize,
    start_step: u64,
    resume: Option<&[PipelineState]>,
) -> anyhow::Result<Infeed> {
    let batch = m.batch();
    let seq = m.seq_len();
    let arch = m.arch.clone();
    let dir = cache_dir.to_path_buf();
    Infeed::spawn_resumable(
        m,
        num_hosts,
        4,
        move |host| {
            let p = DeterministicPipeline::open(&dir).expect("open cache");
            let ds = p
                .host_stream(host, num_hosts, start_step as usize * batch, true)
                .map(strip_index);
            if arch == "encdec" {
                let tl = lengths(&[("inputs", seq), ("targets", seq)]);
                EncDecConverter.convert(ds, &tl)
            } else {
                let tl = lengths(&[("targets", seq)]);
                LmConverter.convert(ds, &tl)
            }
        },
        resume,
    )
}

/// Eval batches straight from a task (no cache), converter per arch.
pub fn eval_batches(
    m: &ModelManifest,
    task: &Task,
    seed: u64,
    num_batches: usize,
) -> Vec<Vec<crate::runtime::HostTensor>> {
    let seq = m.seq_len();
    let ds = task.dataset(seed, 0, 1);
    let converted = if m.arch == "encdec" {
        let tl = lengths(&[("inputs", seq), ("targets", seq)]);
        EncDecConverter.convert(ds, &tl)
    } else {
        let tl = lengths(&[("targets", seq)]);
        LmConverter.convert(ds, &tl)
    };
    let examples = converted.collect_vec();
    examples
        .chunks(m.batch())
        .filter(|c| c.len() == m.batch())
        .take(num_batches)
        .map(|c| crate::trainer::infeed::assemble_batch(m, c))
        .collect()
}

/// Raw (target, source-pairs) for decode-based evaluation of the
/// reverse-words task: returns (enc_batch_tensors, target_strings).
pub fn decode_eval_set(
    m: &ModelManifest,
    task: &Task,
    seed: u64,
) -> (Vec<crate::runtime::HostTensor>, Vec<String>, Vec<String>) {
    assert_eq!(m.arch, "encdec");
    let seq = m.seq_len();
    let examples = task.dataset(seed, 0, 1).take(m.batch()).collect_vec();
    assert_eq!(examples.len(), m.batch(), "not enough eval examples");
    let tl = lengths(&[("inputs", seq), ("targets", seq)]);
    let converted: Vec<_> = examples
        .iter()
        .map(|e| EncDecConverter.convert_example(e, &tl))
        .collect();
    let batch = crate::trainer::infeed::assemble_batch(m, &converted);
    let enc = batch[0].clone();
    let targets: Vec<String> = examples
        .iter()
        .map(|e| e["targets_text"].as_text().unwrap_or("").to_string())
        .collect();
    let inputs: Vec<String> = examples
        .iter()
        .map(|e| e["inputs_text"].as_text().unwrap_or("").to_string())
        .collect();
    (vec![enc], targets, inputs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Artifacts;

    #[test]
    fn reverse_task_produces_learnable_pairs() {
        let task = reverse_words_task("rev_test", 10, 1);
        let exs = task.dataset(0, 0, 1).collect_vec();
        assert_eq!(exs.len(), 10);
        for ex in &exs {
            let inp = ex["inputs_text"].as_text().unwrap();
            let tgt = ex["targets_text"].as_text().unwrap();
            let rev: Vec<&str> = inp.split_whitespace().rev().collect();
            assert_eq!(tgt, rev.join(" "));
            assert!(!ex["inputs"].as_ints().unwrap().is_empty());
        }
    }

    #[test]
    fn eval_batches_shapes() {
        let arts = Artifacts::load_default().unwrap();
        let m = arts.model("t5-nano-dec").unwrap();
        let task = lm_task("recipes_eval_lm", 100, m.seq_len(), 3);
        let batches = eval_batches(m, &task, 0, 3);
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].len(), 3);
        assert_eq!(batches[0][0].shape, vec![m.batch(), m.seq_len()]);
    }

    #[test]
    fn ensure_cached_idempotent() {
        let dir = std::env::temp_dir().join(format!("recipes_cache_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let task = lm_task("recipes_cache_lm", 50, 32, 1);
        let m1 = ensure_cached(&task, &dir, 4, 9).unwrap();
        let mtime1 = std::fs::metadata(dir.join("cache_meta.json")).unwrap().modified().unwrap();
        let m2 = ensure_cached(&task, &dir, 4, 9).unwrap();
        let mtime2 = std::fs::metadata(dir.join("cache_meta.json")).unwrap().modified().unwrap();
        assert_eq!(m1.num_examples, m2.num_examples);
        assert_eq!(mtime1, mtime2, "cache should not be rebuilt");
        std::fs::remove_dir_all(&dir).ok();
    }
}
