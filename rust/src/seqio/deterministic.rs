//! Deterministic pipeline reader (paper §3.2). Provides the four
//! properties over a directory produced by [`super::cache`]:
//!
//! * **Reproducibility** — examples always arrive in global index order.
//! * **Recoverability** — `start_at(k)` resumes the stream at the k-th
//!   example of this host, in O(num_host_files) seeks (sidecar indices),
//!   so restarts never repeat or skip data.
//! * **Sharding** — host h of H reads exactly the indices i ≡ h (mod H);
//!   because files hold indices i ≡ f (mod N) and H divides N, host h
//!   touches only files f ≡ h (mod H): an *exclusive, sequentially
//!   readable* file set (the throughput claim, E9).
//! * **Global shuffle** — performed once by the offline cache job.
//!
//! The reader emits each example with an extra `_index` int feature (its
//! global index), which tests and the trainer's data-order audits use.

use std::path::{Path, PathBuf};

use super::cache::CacheMeta;
use super::dataset::{check_tag, field_usize, Dataset, PipelineOp};
use super::records::RecordReader;
use super::{deserialize_example, Example, Feature};
use crate::util::json::Json;

/// Handle to a cached deterministic task directory.
pub struct DeterministicPipeline {
    pub dir: PathBuf,
    pub meta: CacheMeta,
}

impl DeterministicPipeline {
    pub fn open(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let meta = CacheMeta::load(&dir)?;
        Ok(Self { dir, meta })
    }

    /// Number of examples host `h` of `num_hosts` owns.
    pub fn host_examples(&self, host: usize, num_hosts: usize) -> usize {
        (self.meta.num_examples + num_hosts - 1 - host) / num_hosts
    }

    /// The exclusive file set of host `h` (paper's sequential-read claim).
    pub fn host_files(&self, host: usize, num_hosts: usize) -> Vec<usize> {
        assert!(
            self.meta.num_shards % num_hosts == 0,
            "num_shards ({}) must be a multiple of num_hosts ({num_hosts})",
            self.meta.num_shards
        );
        (0..self.meta.num_shards)
            .filter(|f| f % num_hosts == host)
            .collect()
    }

    /// Stream host `h`'s examples starting from its `start_k`-th example
    /// (start_k = step * per_host_batch for resume), in global index order,
    /// optionally repeating over epochs.
    ///
    /// The returned dataset is a stateful [`PipelineOp`]: its op state is
    /// the total-emitted cursor (`start_at` position), so trainer restarts
    /// can snapshot and seek it in O(1) via the sidecar record indices.
    pub fn host_stream(
        &self,
        host: usize,
        num_hosts: usize,
        start_k: usize,
        repeat: bool,
    ) -> Dataset {
        self.try_host_stream(host, num_hosts, start_k, repeat)
            .expect("open cache shard files")
    }

    /// Fallible variant of [`DeterministicPipeline::host_stream`] — a
    /// missing/unreadable shard file surfaces as an error instead of a
    /// panic (the `DatasetProvider` contract).
    pub fn try_host_stream(
        &self,
        host: usize,
        num_hosts: usize,
        start_k: usize,
        repeat: bool,
    ) -> anyhow::Result<Dataset> {
        let files = self.host_files(host, num_hosts);
        let mut readers: Vec<RecordReader> = Vec::with_capacity(files.len());
        for &f in &files {
            readers.push(
                RecordReader::open(CacheMeta::shard_file(&self.dir, f)).map_err(|e| {
                    anyhow::anyhow!("cache at {}: shard file {f}: {e}", self.dir.display())
                })?,
            );
        }
        let mut hr = HostReader {
            readers,
            r: 0,
            q: 0,
            shard_ids: files,
            n: self.meta.num_examples,
            shards: self.meta.num_shards,
            emitted: 0,
            total_emitted: 0,
            per_host: self.host_examples(host, num_hosts),
            repeat,
        };
        hr.seek(start_k);
        Ok(Dataset::from_op(hr))
    }

    /// Convenience: the merged global-order stream (single host view).
    pub fn global_stream(&self) -> Dataset {
        self.host_stream(0, 1, 0, false)
    }
}

/// The stateful reader behind [`DeterministicPipeline::host_stream`]. Its
/// entire position is one number — the total examples emitted — which the
/// trainer snapshots at batch boundaries and the restore path seeks to.
struct HostReader {
    readers: Vec<RecordReader>,
    /// file index within `readers` to pull from next
    r: usize,
    /// entry index within that file
    q: usize,
    /// absolute shard number per reader (for global index calc)
    shard_ids: Vec<usize>,
    n: usize,
    shards: usize,
    /// emitted within the current epoch
    emitted: usize,
    /// emitted across all epochs (the `start_at` cursor reported as state)
    total_emitted: usize,
    per_host: usize,
    repeat: bool,
}

impl HostReader {
    /// Position the reader so the next example is the `k_total`-th this
    /// host would emit overall. Wraps for repeating streams; clamps (=>
    /// empty stream) for finite ones resumed past their end.
    fn seek(&mut self, k_total: usize) {
        let m = self.readers.len().max(1);
        let k = if self.repeat {
            k_total % self.per_host.max(1)
        } else {
            k_total.min(self.per_host)
        };
        self.r = k % m;
        self.q = k / m;
        self.emitted = k;
        self.total_emitted = k_total;
    }

    fn advance(&mut self) {
        self.r += 1;
        if self.r == self.readers.len() {
            self.r = 0;
            self.q += 1;
        }
    }

    fn reset_epoch(&mut self) {
        self.r = 0;
        self.q = 0;
        self.emitted = 0;
        for rd in &mut self.readers {
            let _ = rd.seek_to(0);
        }
    }
}

impl PipelineOp for HostReader {
    fn next(&mut self) -> Option<Example> {
        loop {
            if self.emitted >= self.per_host {
                if self.repeat {
                    self.reset_epoch();
                } else {
                    return None;
                }
            }
            let shard = self.shard_ids[self.r];
            let global_index = self.q * self.shards + shard;
            if global_index >= self.n {
                // ragged tail: this file has no entry q; advance.
                self.advance();
                continue;
            }
            let payload = self.readers[self.r]
                .read_at(self.q)
                .expect("deterministic read");
            let mut ex = deserialize_example(&payload).expect("deserialize example");
            ex.insert("_index".into(), Feature::Ints(vec![global_index as i32]));
            self.advance();
            self.emitted += 1;
            self.total_emitted += 1;
            return Some(ex);
        }
    }

    fn state(&mut self) -> Json {
        Json::obj(vec![
            ("op", Json::str("det_reader")),
            ("emitted_total", Json::num(self.total_emitted as f64)),
        ])
    }

    fn restore(&mut self, s: &Json) -> anyhow::Result<()> {
        check_tag(s, "det_reader")?;
        self.seek(field_usize(s, "emitted_total")?);
        Ok(())
    }
}

/// Strip the bookkeeping `_index` feature (before feeding converters).
pub fn strip_index(mut ex: Example) -> Example {
    ex.remove("_index");
    ex
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::seqio::cache::{cache_task, CacheConfig};
    use crate::seqio::preprocessors::Tokenize;
    use crate::seqio::source::SyntheticTextSource;
    use crate::seqio::task::Task;
    use crate::seqio::vocab::{ByteVocabulary, Vocabulary};
    use std::sync::Arc;

    fn build_cache(n: usize, shards: usize, tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("det_{}_{tag}", std::process::id()));
        let vocab: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(16));
        let task = Task::builder("det_test_task")
            .source(Arc::new(SyntheticTextSource::new(7, n)))
            .preprocessor(Arc::new(Tokenize::new(vocab.clone(), &[("text", "targets")])))
            .output_feature("targets", vocab, true)
            .build();
        cache_task(&task, &dir, &CacheConfig { num_shards: shards, seed: 1, workers: 2 })
            .unwrap();
        dir
    }

    fn indices(ds: Dataset) -> Vec<i32> {
        ds.collect_vec()
            .iter()
            .map(|e| e["_index"].as_ints().unwrap()[0])
            .collect()
    }

    #[test]
    fn global_stream_is_index_ordered() {
        let dir = build_cache(41, 8, "order");
        let p = DeterministicPipeline::open(&dir).unwrap();
        let idx = indices(p.global_stream());
        assert_eq!(idx, (0..41).collect::<Vec<_>>());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn host_shards_partition_and_interleave() {
        let dir = build_cache(40, 8, "shard");
        let p = DeterministicPipeline::open(&dir).unwrap();
        let h0 = indices(p.host_stream(0, 4, 0, false));
        let h1 = indices(p.host_stream(1, 4, 0, false));
        // host h sees exactly indices ≡ h (mod 4), in order
        assert_eq!(h0, (0..40).step_by(4).collect::<Vec<_>>());
        assert_eq!(h1, (1..40).step_by(4).collect::<Vec<_>>());
        // exclusive file sets
        assert_eq!(p.host_files(0, 4), vec![0, 4]);
        assert_eq!(p.host_files(1, 4), vec![1, 5]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resume_matches_continuous_stream() {
        let dir = build_cache(50, 4, "resume");
        let p = DeterministicPipeline::open(&dir).unwrap();
        let full = indices(p.host_stream(1, 2, 0, false));
        for start_k in [0usize, 1, 5, 11, 24] {
            let resumed = indices(p.host_stream(1, 2, start_k, false));
            assert_eq!(resumed, full[start_k..], "start_k={start_k}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn repeat_wraps_epochs() {
        let dir = build_cache(10, 2, "repeat");
        let p = DeterministicPipeline::open(&dir).unwrap();
        let idx: Vec<i32> = p
            .host_stream(0, 1, 0, true)
            .take(25)
            .collect_vec()
            .iter()
            .map(|e| e["_index"].as_ints().unwrap()[0])
            .collect();
        assert_eq!(&idx[0..10], (0..10).collect::<Vec<_>>().as_slice());
        assert_eq!(&idx[10..20], (0..10).collect::<Vec<_>>().as_slice());
        assert_eq!(&idx[20..25], (0..5).collect::<Vec<_>>().as_slice());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ragged_tail_handled() {
        // 13 examples over 4 shards: files have 4,3,3,3 entries.
        let dir = build_cache(13, 4, "ragged");
        let p = DeterministicPipeline::open(&dir).unwrap();
        let idx = indices(p.global_stream());
        assert_eq!(idx, (0..13).collect::<Vec<_>>());
        let h1 = indices(p.host_stream(1, 2, 0, false));
        assert_eq!(h1, vec![1, 3, 5, 7, 9, 11]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mismatched_host_count_panics() {
        let dir = build_cache(10, 4, "mismatch");
        let p = DeterministicPipeline::open(&dir).unwrap();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            p.host_files(0, 3)
        }));
        assert!(r.is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
