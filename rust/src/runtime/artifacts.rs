//! Artifact manifest: the contract between `python/compile/aot.py` (L2/L1)
//! and the Rust coordinator. Parsed from `artifacts/manifest.json`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One model parameter: name, shape, logical axes (t5x `param_with_axes`),
/// and an init spec ("normal:<stddev>" or "const:<value>").
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub logical_axes: Vec<String>,
    pub init: String,
}

impl ParamSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One batch feature expected by the entrypoints.
#[derive(Debug, Clone)]
pub struct FeatureSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub is_int: bool,
}

/// One exported HLO computation.
#[derive(Debug, Clone)]
pub struct Entrypoint {
    pub hlo: PathBuf,
    pub outputs: Vec<String>,
}

/// Everything the coordinator knows about one exported model.
#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    pub arch: String,
    pub config: BTreeMap<String, f64>,
    pub params: Vec<ParamSpec>,
    pub batch_features: Vec<FeatureSpec>,
    pub entrypoints: BTreeMap<String, Entrypoint>,
}

impl ModelManifest {
    pub fn total_params(&self) -> usize {
        self.params.iter().map(|p| p.elements()).sum()
    }

    pub fn param(&self, name: &str) -> Option<&ParamSpec> {
        self.params.iter().find(|p| p.name == name)
    }

    pub fn entrypoint(&self, name: &str) -> anyhow::Result<&Entrypoint> {
        self.entrypoints
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("model {} has no entrypoint {name}", self.name))
    }

    pub fn cfg_usize(&self, key: &str) -> usize {
        *self.config.get(key).unwrap_or(&0.0) as usize
    }

    /// Per-host batch size baked into the HLO.
    pub fn batch(&self) -> usize {
        self.cfg_usize("batch")
    }

    pub fn seq_len(&self) -> usize {
        self.cfg_usize("seq_len")
    }

    pub fn vocab(&self) -> usize {
        self.cfg_usize("vocab")
    }

    /// Tokens contributing to a train step on one host.
    pub fn tokens_per_step(&self) -> usize {
        self.batch() * self.seq_len()
    }
}

/// The parsed manifest + artifact directory.
#[derive(Debug, Clone)]
pub struct Artifacts {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelManifest>,
    /// Compile-bench HLOs (scan vs unroll), name -> path.
    pub bench: BTreeMap<String, PathBuf>,
    /// Partitioning-demo HLOs + dims.
    pub partdemo: Option<PartDemo>,
}

#[derive(Debug, Clone)]
pub struct PartDemo {
    pub m: usize,
    pub k: usize,
    pub f: usize,
    pub hlos: BTreeMap<String, PathBuf>,
}

impl Artifacts {
    /// Default location: `$T5X_ARTIFACTS` or `artifacts/` under the cwd /
    /// the cargo manifest dir (so tests work from any directory).
    pub fn default_dir() -> PathBuf {
        if let Ok(p) = std::env::var("T5X_ARTIFACTS") {
            return PathBuf::from(p);
        }
        let cwd = PathBuf::from("artifacts");
        if cwd.join("manifest.json").exists() {
            return cwd;
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    pub fn load_default() -> anyhow::Result<Artifacts> {
        Self::load(Self::default_dir())
    }

    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Artifacts> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Json::parse_file(dir.join("manifest.json"))?;
        let mut models = BTreeMap::new();
        if let Some(Json::Obj(m)) = manifest.get("models") {
            for (name, jm) in m {
                models.insert(name.clone(), parse_model(name, jm, &dir)?);
            }
        }
        let mut bench = BTreeMap::new();
        if let Some(Json::Obj(b)) = manifest.get("bench") {
            for (name, path) in b {
                if let Some(p) = path.as_str() {
                    bench.insert(name.clone(), dir.join(p));
                }
            }
        }
        let partdemo = manifest.get("partdemo").map(|pd| {
            let mut hlos = BTreeMap::new();
            if let Some(Json::Obj(h)) = pd.get("hlos") {
                for (name, path) in h {
                    if let Some(p) = path.as_str() {
                        hlos.insert(name.clone(), dir.join(p));
                    }
                }
            }
            PartDemo {
                m: pd.get("m").and_then(|v| v.as_usize()).unwrap_or(0),
                k: pd.get("k").and_then(|v| v.as_usize()).unwrap_or(0),
                f: pd.get("f").and_then(|v| v.as_usize()).unwrap_or(0),
                hlos,
            }
        });
        Ok(Artifacts { dir, models, bench, partdemo })
    }

    pub fn model(&self, name: &str) -> anyhow::Result<&ModelManifest> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("model '{name}' not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()))
    }
}

fn parse_model(name: &str, j: &Json, dir: &Path) -> anyhow::Result<ModelManifest> {
    let arch = j.get("arch").and_then(|v| v.as_str()).unwrap_or("decoder").to_string();
    let mut config = BTreeMap::new();
    if let Some(Json::Obj(c)) = j.get("config") {
        for (k, v) in c {
            if let Some(n) = v.as_f64() {
                config.insert(k.clone(), n);
            }
        }
    }
    let mut params = Vec::new();
    for p in j.get("params").and_then(|v| v.as_arr()).unwrap_or(&[]) {
        params.push(ParamSpec {
            name: p.get("name").and_then(|v| v.as_str()).unwrap_or("").to_string(),
            shape: p
                .get("shape")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default(),
            logical_axes: p
                .get("logical_axes")
                .and_then(|v| v.as_arr())
                .map(|a| {
                    a.iter()
                        .filter_map(|x| x.as_str().map(|s| s.to_string()))
                        .collect()
                })
                .unwrap_or_default(),
            init: p.get("init").and_then(|v| v.as_str()).unwrap_or("const:0").to_string(),
        });
    }
    let mut batch_features = Vec::new();
    for f in j.get("batch_features").and_then(|v| v.as_arr()).unwrap_or(&[]) {
        batch_features.push(FeatureSpec {
            name: f.get("name").and_then(|v| v.as_str()).unwrap_or("").to_string(),
            shape: f
                .get("shape")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default(),
            is_int: f.get("dtype").and_then(|v| v.as_str()) == Some("i32"),
        });
    }
    let mut entrypoints = BTreeMap::new();
    if let Some(Json::Obj(eps)) = j.get("entrypoints") {
        for (ep_name, ep) in eps {
            entrypoints.insert(
                ep_name.clone(),
                Entrypoint {
                    hlo: dir.join(ep.get("hlo").and_then(|v| v.as_str()).unwrap_or("")),
                    outputs: ep
                        .get("outputs")
                        .and_then(|v| v.as_arr())
                        .map(|a| {
                            a.iter()
                                .filter_map(|x| x.as_str().map(|s| s.to_string()))
                                .collect()
                        })
                        .unwrap_or_default(),
                },
            );
        }
    }
    Ok(ModelManifest { name: name.to_string(), arch, config, params, batch_features, entrypoints })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_real_manifest() {
        let a = Artifacts::load_default().expect("run `make artifacts` first");
        let m = a.model("t5-nano-dec").unwrap();
        assert_eq!(m.arch, "decoder");
        assert!(m.total_params() > 100_000);
        assert!(m.entrypoint("train_step").is_ok());
        assert!(m.entrypoint("eval_step").is_ok());
        assert!(m.entrypoint("decode_logits").is_ok());
        // params sorted by name, embed present with vocab axis
        let emb = m.param("token_embed").unwrap();
        assert_eq!(emb.logical_axes, vec!["vocab", "embed"]);
        assert_eq!(emb.shape, vec![m.vocab(), 64]);
        // train outputs: 3 scalars + one grad per param
        let ep = m.entrypoint("train_step").unwrap();
        assert_eq!(ep.outputs.len(), 3 + m.params.len());
        assert!(ep.hlo.exists());
        // bench + partdemo artifacts present
        assert!(a.bench.contains_key("scan_L4"));
        assert!(a.partdemo.as_ref().unwrap().hlos.contains_key("ffn_full"));
    }

    #[test]
    fn encdec_manifest_features() {
        let a = Artifacts::load_default().unwrap();
        let m = a.model("t5-nano-encdec").unwrap();
        let names: Vec<&str> = m.batch_features.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "encoder_input_tokens",
                "decoder_input_tokens",
                "decoder_target_tokens",
                "decoder_loss_weights"
            ]
        );
        assert!(m.batch_features[0].is_int);
        assert!(!m.batch_features[3].is_int);
    }
}
