//! The explicit step schedule: one train step is a plan of `{Compute,
//! Comm}` tasks over `k` gradient-accumulation microbatches, executed by a
//! per-host [`StepRunner`] whose [`CommLane`] runs ring collectives off the
//! host thread.
//!
//! ```text
//! serial (overlap = false), k = 3 — every reduce is exposed:
//!
//!   host:  I0 C0 ····· I1 C1 ····· I2 C2 ····· F
//!   lane:        R0          R1          R2
//!                └─ host blocked ─┘ (wait immediately after dispatch)
//!
//! overlapped (overlap = true), k = 3 — reduce j rides under compute j+1:
//!
//!   host:  I0 C0 I1 C1 w0 I2 C2 w1 w2 F
//!   lane:        R0───┘ R1────┘ R2─┘
//! ```
//!
//! `I` = infeed, `C` = forward/backward, `R` = the microbatch's data-axis
//! gradient reduce executing on the lane, `w` = the (short) join of an
//! already-finished reduce, `F` = finalize (scalar sync, clip, optimizer).
//!
//! **Numerics contract.** Gradients are reduced *per microbatch* and
//! accumulated strictly in microbatch order (`acc = ((r0 + r1) + r2)…`),
//! whether or not overlap is enabled — the serial and overlapped plans
//! reorder only wall-clock execution, never the f32 summation tree, so
//! `overlap on/off` are bit-identical. On a 1-host data axis the reduce is
//! the identity and the accumulation equals the monolithic left-fold over
//! the same `k` batches (asserted by `microbatched_k_is_bit_identical_…`
//! in `tests/integration_sharded.rs`).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::collectives::{CommLane, PendingCollective};

/// Which engine executes a planned task: the host thread (`Compute`) or
/// the host's dedicated communication lane (`Comm`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lane {
    Compute,
    Comm,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    /// Obtain microbatch `j`'s batch (pull + row broadcast).
    Infeed,
    /// Forward/backward of microbatch `j` (param gathers + HLO execution).
    ForwardBackward,
    /// Enqueue microbatch `j`'s data-axis gradient reduce on the comm lane.
    DispatchGradReduce,
    /// Join microbatch `j`'s gradient reduce and accumulate its result.
    WaitGradReduce,
    /// Step-final work: scalar all-reduce, clip norm, optimizer update.
    Finalize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlannedTask {
    pub lane: Lane,
    pub kind: TaskKind,
    pub microbatch: usize,
}

/// Build the task schedule of one train step. With `overlap`, the wait for
/// microbatch `j`'s reduce is placed *after* microbatch `j+1`'s dispatch,
/// so the ring runs under the next forward/backward; without it, each
/// dispatch is joined immediately (same op sequence, fully exposed).
pub fn plan_step(microbatches: usize, overlap: bool) -> Vec<PlannedTask> {
    let k = microbatches.max(1);
    let t = |lane, kind, j| PlannedTask { lane, kind, microbatch: j };
    let mut plan = Vec::with_capacity(4 * k + 1);
    for j in 0..k {
        plan.push(t(Lane::Compute, TaskKind::Infeed, j));
        plan.push(t(Lane::Compute, TaskKind::ForwardBackward, j));
        plan.push(t(Lane::Comm, TaskKind::DispatchGradReduce, j));
        if overlap {
            if j > 0 {
                plan.push(t(Lane::Comm, TaskKind::WaitGradReduce, j - 1));
            }
        } else {
            plan.push(t(Lane::Comm, TaskKind::WaitGradReduce, j));
        }
    }
    if overlap {
        plan.push(t(Lane::Comm, TaskKind::WaitGradReduce, k - 1));
    }
    plan.push(t(Lane::Compute, TaskKind::Finalize, 0));
    plan
}

/// Per-host executor of a step plan: owns the communication lane and the
/// exposed-vs-overlapped accounting. Host-thread time blocked on a comm op
/// lands in the shared data-axis collective phase (it *is* exposed comm
/// time); lane execution the host did not block for accumulates into the
/// trainer's `overlapped_comm_micros`.
pub struct StepRunner<'a> {
    lane: CommLane,
    coll_data: &'a super::PhaseTimer,
    overlapped: &'a AtomicU64,
}

impl<'a> StepRunner<'a> {
    pub fn new(
        lane: CommLane,
        coll_data: &'a super::PhaseTimer,
        overlapped: &'a AtomicU64,
    ) -> StepRunner<'a> {
        StepRunner { lane, coll_data, overlapped }
    }

    pub fn lane(&self) -> &CommLane {
        &self.lane
    }

    /// Enqueue a comm op; returns immediately (the `DispatchGradReduce`
    /// primitive).
    pub fn dispatch<T: Send + 'static>(
        &self,
        label: &'static str,
        f: impl FnOnce() -> T + Send + 'static,
    ) -> PendingCollective<T> {
        self.lane.submit(label, f)
    }

    /// Join a dispatched op (the `WaitGradReduce` primitive): blocked time
    /// is exposed comm, the rest of the op's lane time was overlapped.
    pub fn settle<T>(&self, pending: PendingCollective<T>) -> T {
        let (v, stats) = pending.wait_stats();
        self.coll_data.add_micros(stats.blocked_micros);
        self.overlapped.fetch_add(
            stats.exec_micros.saturating_sub(stats.blocked_micros),
            Ordering::Relaxed,
        );
        v
    }

    /// Run a comm op on the lane and wait for it — lane-routed so it keeps
    /// FIFO order with in-flight dispatches on the same group (block
    /// execution's data-axis shard gathers), fully exposed.
    pub fn sync<T: Send + 'static>(
        &self,
        label: &'static str,
        f: impl FnOnce() -> T + Send + 'static,
    ) -> T {
        let (v, stats) = self.lane.run(label, f);
        self.coll_data.add_micros(stats.blocked_micros);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pos(plan: &[PlannedTask], kind: TaskKind, j: usize) -> usize {
        plan.iter()
            .position(|t| t.kind == kind && t.microbatch == j)
            .unwrap_or_else(|| panic!("plan misses {kind:?} for microbatch {j}"))
    }

    #[test]
    fn plan_has_every_task_exactly_once_per_microbatch() {
        for k in [1, 2, 4] {
            for overlap in [false, true] {
                let plan = plan_step(k, overlap);
                assert_eq!(plan.len(), 4 * k + 1, "k={k} overlap={overlap}");
                for j in 0..k {
                    for kind in [
                        TaskKind::Infeed,
                        TaskKind::ForwardBackward,
                        TaskKind::DispatchGradReduce,
                        TaskKind::WaitGradReduce,
                    ] {
                        let n = plan
                            .iter()
                            .filter(|t| t.kind == kind && t.microbatch == j)
                            .count();
                        assert_eq!(n, 1, "k={k} overlap={overlap} {kind:?} mb={j}");
                    }
                }
                assert_eq!(plan.last().unwrap().kind, TaskKind::Finalize);
            }
        }
    }

    #[test]
    fn waits_follow_dispatches_and_accumulate_in_order() {
        for k in [1, 2, 4] {
            for overlap in [false, true] {
                let plan = plan_step(k, overlap);
                let mut last_wait = 0;
                for j in 0..k {
                    let d = pos(&plan, TaskKind::DispatchGradReduce, j);
                    let w = pos(&plan, TaskKind::WaitGradReduce, j);
                    assert!(w > d, "wait {j} must follow its dispatch");
                    assert!(w >= last_wait, "waits must run in microbatch order");
                    last_wait = w;
                }
            }
        }
    }

    #[test]
    fn overlap_places_wait_under_next_compute() {
        let k = 4;
        let plan = plan_step(k, true);
        for j in 0..k - 1 {
            let w = pos(&plan, TaskKind::WaitGradReduce, j);
            let c_next = pos(&plan, TaskKind::ForwardBackward, j + 1);
            let d_next = pos(&plan, TaskKind::DispatchGradReduce, j + 1);
            assert!(
                w > c_next && w > d_next,
                "overlapped wait {j} must come after microbatch {}'s compute + dispatch",
                j + 1
            );
        }
        // serial: every wait precedes the next microbatch's compute
        let serial = plan_step(k, false);
        for j in 0..k - 1 {
            let w = pos(&serial, TaskKind::WaitGradReduce, j);
            let c_next = pos(&serial, TaskKind::ForwardBackward, j + 1);
            assert!(w < c_next, "serial wait {j} must precede compute {}", j + 1);
        }
    }

    #[test]
    fn k1_overlap_plan_equals_serial_plan() {
        assert_eq!(plan_step(1, true), plan_step(1, false));
    }

    #[test]
    fn comm_tasks_are_marked_comm_lane() {
        for t in plan_step(3, true) {
            let expect = matches!(
                t.kind,
                TaskKind::DispatchGradReduce | TaskKind::WaitGradReduce
            );
            assert_eq!(t.lane == Lane::Comm, expect, "{t:?}");
        }
    }
}
