//! # seqio-rs
//!
//! A Rust port of seqio (paper §3): task-based data pipelines for training,
//! inference and evaluation, with first-class *deterministic pipelines*.
//!
//! Structure mirrors Figure 2 of the paper, unified behind the single
//! [`get_dataset`] entry point (§3.1):
//!
//! ```text
//!              get_dataset(name_or_provider, GetDatasetOptions)
//!                                 |
//!                     ProviderRegistry  [provider.rs]
//!              (one namespace: tasks + mixtures + caches;
//!               duplicate registration is an error)
//!                  /              |               \
//!              Task            Mixture          CachedTask
//!            [task.rs]       [mixture.rs]      [provider.rs]
//!                |                                  |
//!   DataSource -> Preprocessors          DeterministicPipeline (§3.2)
//!   [source.rs]  [preprocessors.rs]      [cache.rs / deterministic.rs]
//!       (per split: train/validation/...)           |
//!                  \_______________________________/
//!                                 |
//!                    FeatureConverter (per model arch)
//!                      [feature_converters.rs]
//!                                 |
//!              model-ready, checkpoint-resumable Dataset
//!                           [dataset.rs]
//! ```
//!
//! Every [`provider::DatasetProvider`] — live [`task::Task`], weighted
//! [`mixture::Mixture`], or offline [`provider::CachedTask`] — declares
//! its splits and output features and yields the same kind of stateful,
//! resumable example stream, so the trainer, evaluator and cache job all
//! resolve their data *by registry name* ([`get_dataset`]) and never care
//! which kind serves it.
//!
//! Deterministic pipelines (§3.2) are provided by an offline cache job
//! ([`cache`]) that preprocesses, globally shuffles, assigns ordered
//! indices, and writes examples sharded by `index % num_files`
//! ([`records`]), plus a deterministic reader ([`deterministic`]) that
//! gives every data-parallel host an exclusive, sequentially-readable set
//! of files, supports exact resume at an arbitrary step, and never repeats
//! data after restarts. [`provider::CachedTask`] wraps that reader as a
//! provider, making offline caches interchangeable with live tasks.

pub mod cache;
pub mod dataset;
pub mod deterministic;
pub mod evaluation;
pub mod feature_converters;
pub mod mixture;
pub mod preprocessors;
pub mod provider;
pub mod records;
pub mod source;
pub mod task;
pub mod vocab;

pub use provider::{
    get_dataset, CachedTask, DatasetProvider, GetDatasetOptions, ProviderRef,
    ProviderRegistry, RegistryEntry, ShardInfo,
};

use std::collections::BTreeMap;

/// One feature value of an example.
#[derive(Debug, Clone, PartialEq)]
pub enum Feature {
    Text(String),
    Ints(Vec<i32>),
    Floats(Vec<f32>),
}

impl Feature {
    pub fn as_text(&self) -> Option<&str> {
        match self {
            Feature::Text(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_ints(&self) -> Option<&[i32]> {
        match self {
            Feature::Ints(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_floats(&self) -> Option<&[f32]> {
        match self {
            Feature::Floats(v) => Some(v),
            _ => None,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Feature::Text(s) => s.len(),
            Feature::Ints(v) => v.len(),
            Feature::Floats(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// An example: named features. BTreeMap for deterministic iteration.
pub type Example = BTreeMap<String, Feature>;

/// Convenience constructors used throughout tests and preprocessors.
pub fn text_example(pairs: &[(&str, &str)]) -> Example {
    pairs
        .iter()
        .map(|(k, v)| (k.to_string(), Feature::Text(v.to_string())))
        .collect()
}

pub fn ints_example(pairs: &[(&str, Vec<i32>)]) -> Example {
    pairs
        .iter()
        .map(|(k, v)| (k.to_string(), Feature::Ints(v.clone())))
        .collect()
}

// ---------------------------------------------------------------------------
// Binary example serialization (used by the record cache).
// Layout: u16 n_fields, then per field:
//   u16 name_len | name utf8 | u8 tag | u32 count | payload
// tags: 0=Text (payload utf8), 1=Ints (i32 LE each), 2=Floats (f32 LE each)
// ---------------------------------------------------------------------------

pub fn serialize_example(ex: &Example) -> Vec<u8> {
    let mut out = Vec::with_capacity(64);
    out.extend_from_slice(&(ex.len() as u16).to_le_bytes());
    for (name, feat) in ex {
        out.extend_from_slice(&(name.len() as u16).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        match feat {
            Feature::Text(s) => {
                out.push(0);
                out.extend_from_slice(&(s.len() as u32).to_le_bytes());
                out.extend_from_slice(s.as_bytes());
            }
            Feature::Ints(v) => {
                out.push(1);
                out.extend_from_slice(&(v.len() as u32).to_le_bytes());
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            Feature::Floats(v) => {
                out.push(2);
                out.extend_from_slice(&(v.len() as u32).to_le_bytes());
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
    }
    out
}

#[derive(Debug, thiserror::Error)]
#[error("example deserialization error: {0}")]
pub struct DecodeError(String);

pub fn deserialize_example(buf: &[u8]) -> Result<Example, DecodeError> {
    let mut pos = 0usize;
    fn take<'a>(buf: &'a [u8], pos: &mut usize, n: usize) -> Result<&'a [u8], DecodeError> {
        if *pos + n > buf.len() {
            return Err(DecodeError(format!("truncated at byte {}", *pos)));
        }
        let s = &buf[*pos..*pos + n];
        *pos += n;
        Ok(s)
    }
    let n_fields = u16::from_le_bytes(take(buf, &mut pos, 2)?.try_into().unwrap());
    let mut ex = Example::new();
    for _ in 0..n_fields {
        let name_len =
            u16::from_le_bytes(take(buf, &mut pos, 2)?.try_into().unwrap()) as usize;
        let name = String::from_utf8(take(buf, &mut pos, name_len)?.to_vec())
            .map_err(|e| DecodeError(e.to_string()))?;
        let tag = take(buf, &mut pos, 1)?[0];
        let count =
            u32::from_le_bytes(take(buf, &mut pos, 4)?.try_into().unwrap()) as usize;
        let feat = match tag {
            0 => Feature::Text(
                String::from_utf8(take(buf, &mut pos, count)?.to_vec())
                    .map_err(|e| DecodeError(e.to_string()))?,
            ),
            1 => {
                let bytes = take(buf, &mut pos, count * 4)?;
                Feature::Ints(
                    bytes
                        .chunks_exact(4)
                        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                )
            }
            2 => {
                let bytes = take(buf, &mut pos, count * 4)?;
                Feature::Floats(
                    bytes
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                )
            }
            t => return Err(DecodeError(format!("unknown tag {t}"))),
        };
        ex.insert(name, feat);
    }
    if pos != buf.len() {
        return Err(DecodeError("trailing bytes".into()));
    }
    Ok(ex)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn example_roundtrip() {
        let mut ex = Example::new();
        ex.insert("text".into(), Feature::Text("héllo\nworld".into()));
        ex.insert("ids".into(), Feature::Ints(vec![1, -2, 3_000_000]));
        ex.insert("w".into(), Feature::Floats(vec![0.5, -1.25]));
        let buf = serialize_example(&ex);
        let back = deserialize_example(&buf).unwrap();
        assert_eq!(ex, back);
    }

    #[test]
    fn corrupt_buffer_rejected() {
        let ex = text_example(&[("a", "b")]);
        let mut buf = serialize_example(&ex);
        buf.truncate(buf.len() - 1);
        assert!(deserialize_example(&buf).is_err());
        let mut extended = serialize_example(&ex);
        extended.push(0);
        assert!(deserialize_example(&extended).is_err());
    }
}
