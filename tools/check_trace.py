#!/usr/bin/env python3
"""Validate a Chrome trace-event JSON file written by ``--trace-out``
(stdlib only; the CI smoke job's trace oracle).

Checks:

* the file parses and is either a ``{"traceEvents": [...]}`` envelope or
  a bare event array;
* every event carries a ``ph`` phase; ``X`` (complete) events carry a
  ``name``, numeric ``ts`` and a non-negative ``dur``;
* ``B``/``E`` duration events balance per ``(pid, tid)`` track;
* each ``--require SUBSTR`` matches at least one span name (use it to
  assert instrumentation coverage, e.g. ``--require coll/``).

Usage:

    python tools/check_trace.py trace.json \
        --require coll/ --require seg/ --require train/step

Exit status is non-zero on any violation, with one line per problem on
stderr.
"""

import argparse
import collections
import json
import sys


def load_events(path):
    with open(path, "r", encoding="utf-8") as f:
        v = json.load(f)
    if isinstance(v, dict):
        events = v.get("traceEvents")
        if not isinstance(events, list):
            raise ValueError("envelope has no 'traceEvents' array")
        return events
    if isinstance(v, list):
        return v
    raise ValueError("trace must be an object or an array")


def check(events, require):
    errors = []
    names = collections.Counter()
    counters = set()
    open_begins = collections.Counter()  # (pid, tid) -> B depth
    phases = collections.Counter()
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if not isinstance(ph, str):
            errors.append(f"event {i}: missing 'ph'")
            continue
        phases[ph] += 1
        track = (ev.get("pid"), ev.get("tid"))
        if ph == "X":
            name = ev.get("name")
            if not isinstance(name, str):
                errors.append(f"event {i}: X event without a name")
                continue
            names[name] += 1
            if not isinstance(ev.get("ts"), (int, float)):
                errors.append(f"event {i} ({name}): X event without numeric ts")
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"event {i} ({name}): bad dur {dur!r}")
        elif ph == "B":
            open_begins[track] += 1
        elif ph == "E":
            open_begins[track] -= 1
            if open_begins[track] < 0:
                errors.append(f"event {i}: E without matching B on {track}")
                open_begins[track] = 0
        elif ph == "C":
            counters.add(ev.get("name"))
        elif ph == "M":
            pass
        else:
            errors.append(f"event {i}: unexpected phase {ph!r}")
    for track, depth in open_begins.items():
        if depth != 0:
            errors.append(f"track {track}: {depth} unclosed B event(s)")
    for sub in require:
        if not any(sub in n for n in names):
            errors.append(
                f"--require {sub!r}: no span name contains it "
                f"(spans: {sorted(names)[:20]})"
            )
    return errors, names, counters, phases


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="Chrome trace-event JSON path")
    ap.add_argument(
        "--require",
        action="append",
        default=[],
        metavar="SUBSTR",
        help="fail unless some span name contains SUBSTR (repeatable)",
    )
    args = ap.parse_args()

    try:
        events = load_events(args.trace)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"check_trace: FAIL — {args.trace}: {e}", file=sys.stderr)
        return 1

    errors, names, counters, phases = check(events, args.require)
    spans = sum(names.values())
    print(
        f"{args.trace}: {len(events)} events "
        f"({spans} spans, {len(names)} distinct names, "
        f"{len(counters)} counters; phases {dict(sorted(phases.items()))})"
    )
    for name, n in names.most_common(10):
        print(f"  {n:>6}  {name}")
    if errors:
        for e in errors:
            print(f"check_trace: FAIL — {e}", file=sys.stderr)
        return 1
    if spans == 0:
        print("check_trace: FAIL — trace contains no spans", file=sys.stderr)
        return 1
    print(f"check_trace: ok ({len(args.require)} required name(s) present)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
