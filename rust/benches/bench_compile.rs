//! E12: the Scalable T5 claim (§4) — "an implementation of T5.1.1 using
//! jax.scan to significantly reduce compilation time". Measures PJRT
//! compile time and HLO text size for scan-based vs unrolled lowerings of
//! the same decoder at depths 2/4/8.

use t5x::bench::Bench;
use t5x::runtime::{Artifacts, DeviceHandle};

fn main() {
    let arts = Artifacts::load_default().expect("make artifacts first");
    let device = DeviceHandle::spawn().unwrap();
    let mut bench = Bench::new("compile time: scan vs unroll (E12)");
    let depths: &[usize] = if bench.is_quick() { &[2] } else { &[2, 4, 8] };

    println!(
        "{:<12} {:>14} {:>14} {:>12} {:>12}",
        "depth", "scan compile", "unroll compile", "scan KiB", "unroll KiB"
    );
    for &depth in depths {
        let mut times = [0.0f64; 2];
        let mut sizes = [0usize; 2];
        for (i, kind) in ["scan", "unroll"].iter().enumerate() {
            let name = format!("{kind}_L{depth}");
            let path = &arts.bench[&name];
            sizes[i] = std::fs::metadata(path).unwrap().len() as usize;
            // measure via the bench harness (compile is the workload)
            let mes = bench.measure(&format!("compile {name}"), || {
                let (exe, _) = device.compile(path).unwrap();
                exe.release();
            });
            times[i] = mes.median_s;
        }
        println!(
            "{:<12} {:>14} {:>14} {:>12} {:>12}",
            depth,
            t5x::bench::human_time(times[0]),
            t5x::bench::human_time(times[1]),
            sizes[0] / 1024,
            sizes[1] / 1024
        );
    }
    println!("\n(scan compiles a single layer body; unroll recompiles every layer —");
    println!(" the gap widens with depth, which is the Scalable T5 motivation)");
    bench.write_jsonl("bench_results.jsonl").unwrap();
    device.shutdown();
}
