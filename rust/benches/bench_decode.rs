//! Serving throughput: three-way naive / engine-rescore / engine-kv
//! comparison at several prompt+generation lengths.
//!
//! * **naive** reproduces the pre-engine `cmd_infer` shape: one request at
//!   a time through a full-batch rescore loop (useful work = one row, the
//!   other B-1 slots decode wasted duplicates, every step re-scores the
//!   whole prefix).
//! * **engine rescore** packs requests into the batch slots with
//!   mid-flight refills, but still drives the O(L^2) `decode_logits` HLO.
//! * **engine kv** is the same scheduler on the O(L) `prefill` /
//!   `decode_step` entrypoints ([B, 1] token input per step).
//!
//! Throughput counts *useful* tokens (requested tokens only), so
//! naive->rescore isolates the slot-utilization win and rescore->kv the
//! per-step compute win. Per-step decode seconds come from the engine
//! counters. The L=128 case asserts kv-mode throughput >= rescore-mode —
//! the ISSUE-5 acceptance bar (the gap widens with L; at L=32 the fixed
//! per-call overhead can still hide it).

use t5x::bench::Bench;
use t5x::infer::{DecodeMethod, DecodeMode, InferEngine, InferRequest};
use t5x::runtime::{Artifacts, DeviceHandle};
use t5x::util::json::Json;

/// Append one extra JSONL row to the shared bench log (serve latency
/// percentiles for the BENCH_<pr>.json trajectory).
fn append_row(path: &str, row: &Json) {
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .expect("open bench log");
    writeln!(f, "{row}").expect("append bench row");
}

fn submit_all(engine: &mut InferEngine, prompts: &[Vec<i32>], gen: usize) {
    for (i, p) in prompts.iter().enumerate() {
        engine
            .submit(InferRequest {
                id: i as u64,
                prompt: p.clone(),
                max_tokens: gen,
                method: DecodeMethod::Greedy,
            })
            .unwrap();
    }
}

/// Nearest-rank percentile over an unsorted sample (0 when empty).
fn pct(v: &mut [f64], p: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[idx.min(v.len() - 1)]
}

/// Open-loop Poisson traffic through the serving gateway (§serve):
/// requests arrive on a seeded exponential clock at ~1.2x the calibrated
/// closed-loop engine throughput, so a queue actually forms and the
/// queue-wait / TTFT tails mean something. One pjrt device thread
/// serializes HLO executions, so extra replicas buy scheduling headroom
/// rather than raw FLOPs — the BENCH_8 gate asserts 2-replica throughput
/// holds the single-engine line (ratio >= 0.9), not a 2x.
fn poisson_gateway_bench(arts: &Artifacts, device: &DeviceHandle, quick: bool) {
    use std::sync::mpsc;
    use std::time::{Duration, Instant};
    use t5x::serve::{Gateway, GatewayConfig, ServeOutcome, SubmitOpts};
    use t5x::util::rng::Pcg64;

    let model = "t5-nano-dec";
    if !arts.models.contains_key(model) {
        println!("  SKIP gateway poisson: {model} not in this artifact dir");
        return;
    }
    let m = arts.models.get(model).unwrap().clone();
    let params = t5x::model::init_params(&m, 0);
    let (gen, total) = if quick { (4usize, 24usize) } else { (8, 96) };
    let plen = 3usize;
    let prompts: Vec<Vec<i32>> = (0..total)
        .map(|i| (0..plen).map(|j| ((5 + i * 7 + j * 3) % 400 + 2) as i32).collect())
        .collect();

    // Closed-loop calibration: a full-batch engine sets the service
    // ceiling; the open-loop arrival rate runs 20% hotter than it.
    let mut cal =
        InferEngine::with_mode(arts, device, model, &params, -1, None).unwrap();
    let t0 = Instant::now();
    submit_all(&mut cal, &prompts, gen);
    let done = cal.run_until_idle().unwrap();
    assert_eq!(done.len(), total);
    let cal_tps = (total * gen) as f64 / t0.elapsed().as_secs_f64().max(1e-9);
    let lambda = 1.2 * cal_tps / gen as f64; // arrivals per second
    println!(
        "  gateway poisson: calibrated {cal_tps:.1} tok/s closed-loop -> \
         lambda {lambda:.1} req/s"
    );

    for &n in &[1usize, 2, 4] {
        let mut engines = Vec::with_capacity(n);
        engines
            .push(InferEngine::with_mode(arts, device, model, &params, -1, None).unwrap());
        for _ in 1..n {
            let r = engines[0].replica();
            engines.push(r);
        }
        let gw = Gateway::launch(
            engines,
            GatewayConfig { queue_depth: total.max(1), shed_watermark: None },
        );
        let (tx, rx) = mpsc::channel();
        let mut rng = Pcg64::new(42);
        let mut shed = 0u64;
        let start = Instant::now();
        let mut next_at = 0.0f64;
        for (i, p) in prompts.iter().enumerate() {
            let u = rng.next_f64();
            next_at += -(1.0 - u).ln() / lambda;
            let target = start + Duration::from_secs_f64(next_at);
            let now = Instant::now();
            if target > now {
                std::thread::sleep(target - now);
            }
            let req = InferRequest {
                id: i as u64,
                prompt: p.clone(),
                max_tokens: gen,
                method: DecodeMethod::Greedy,
            };
            // Open loop: an admission rejection is a shed, never a retry.
            if gw.submit(req, SubmitOpts::default(), tx.clone()).is_err() {
                shed += 1;
            }
        }
        drop(tx);
        let mut tokens = 0u64;
        let (mut ttft, mut queue) = (Vec::new(), Vec::new());
        while let Ok(o) = rx.recv() {
            match o {
                ServeOutcome::Done { result, queue_ms, ttft_ms, .. } => {
                    tokens += result.tokens.len() as u64;
                    queue.push(queue_ms);
                    if let Some(t) = ttft_ms {
                        ttft.push(t);
                    }
                }
                _ => shed += 1,
            }
        }
        let report = gw.shutdown();
        assert_eq!(report.completed + shed, total as u64);
        let wall = start.elapsed().as_secs_f64().max(1e-9);
        let tps = tokens as f64 / wall;
        let shed_rate = shed as f64 / total as f64;
        let ttft_p50 = pct(&mut ttft, 50.0);
        let ttft_p99 = pct(&mut ttft, 99.0);
        let queue_p99 = pct(&mut queue, 99.0);
        println!(
            "  gateway poisson x{n}: {tps:.1} tok/s, ttft p50 {ttft_p50:.2} / \
             p99 {ttft_p99:.2} ms, queue p99 {queue_p99:.2} ms, shed \
             {:.1}% ({} completed)",
            shed_rate * 100.0,
            report.completed,
        );
        append_row(
            "bench_results.jsonl",
            &Json::obj(vec![
                ("group", Json::str("serve gateway (poisson)")),
                (
                    "name",
                    Json::str(format!("{model} poisson x{n} ({total} reqs x {gen} tok)")),
                ),
                ("replicas", Json::num(n as f64)),
                ("requests", Json::num(total as f64)),
                ("tok_per_s", Json::num(tps)),
                ("closed_loop_tok_per_s", Json::num(cal_tps)),
                ("ttft_ms_p50", Json::num(ttft_p50)),
                ("ttft_ms_p99", Json::num(ttft_p99)),
                ("queue_ms_p99", Json::num(queue_p99)),
                ("shed_rate", Json::num(shed_rate)),
            ]),
        );
    }
}

fn main() {
    let arts = Artifacts::load_default().expect("make artifacts first");
    let device = DeviceHandle::spawn().unwrap();
    let mut bench = Bench::new("decode serving (infer)");
    // eos -1 never fires: every request decodes exactly `gen` tokens, so
    // all three rows do identical useful work.
    let eos = -1;
    let quick = bench.is_quick();
    // (model, prompt_len, gen_len): nano-dec is the short-sequence case
    // (L=32); nano-dec-l128 stretches the prefix to where O(L^2)
    // rescoring visibly loses (L=128).
    let cases = [
        ("t5-nano-dec", 3usize, if quick { 4usize } else { 8 }),
        ("t5-nano-dec-l128", 8, if quick { 32 } else { 96 }),
    ];
    for (model, plen, gen) in cases {
        let Some(m) = arts.models.get(model) else {
            println!("  SKIP {model}: not in this artifact dir (re-export)");
            continue;
        };
        let m = m.clone();
        let l = m.seq_len();
        let params = t5x::model::init_params(&m, 0);
        for &n in &[1usize, 4, 8] {
            let prompts: Vec<Vec<i32>> = (0..n)
                .map(|i| {
                    (0..plen).map(|j| ((5 + i * 7 + j * 3) % 400 + 2) as i32).collect()
                })
                .collect();
            let useful = (n * gen) as f64;
            let mut naive = InferEngine::with_mode(
                &arts, &device, model, &params, eos, Some(DecodeMode::Rescore),
            )
            .unwrap();
            bench.measure_with_throughput(
                &format!("{model} naive serial rescore ({n} reqs x {gen} tok)"),
                Some((useful, "tok")),
                || {
                    for p in &prompts {
                        naive
                            .submit(InferRequest {
                                id: 0,
                                prompt: p.clone(),
                                max_tokens: gen,
                                method: DecodeMethod::Greedy,
                            })
                            .unwrap();
                        let r = naive.run_until_idle().unwrap();
                        assert_eq!(r[0].tokens.len(), gen);
                    }
                },
            );
            let mut rescore = InferEngine::with_mode(
                &arts, &device, model, &params, eos, Some(DecodeMode::Rescore),
            )
            .unwrap();
            let rescore_tps = bench
                .measure_with_throughput(
                    &format!("{model} engine rescore ({n} reqs x {gen} tok)"),
                    Some((useful, "tok")),
                    || {
                        submit_all(&mut rescore, &prompts, gen);
                        let r = rescore.run_until_idle().unwrap();
                        assert_eq!(r.len(), n);
                    },
                )
                .throughput_per_sec()
                .unwrap();
            let mut kv = InferEngine::with_mode(
                &arts, &device, model, &params, eos, Some(DecodeMode::Kv),
            )
            .expect("kv mode needs prefill/decode_step (re-export artifacts)");
            let kv_tps = bench
                .measure_with_throughput(
                    &format!("{model} engine kv ({n} reqs x {gen} tok)"),
                    Some((useful, "tok")),
                    || {
                        submit_all(&mut kv, &prompts, gen);
                        let r = kv.run_until_idle().unwrap();
                        assert_eq!(r.len(), n);
                    },
                )
                .throughput_per_sec()
                .unwrap();
            let (rs, ks) = (rescore.summary(), kv.summary());
            println!(
                "  {model} n={n}: per-step decode {:.3} ms (rescore) vs {:.3} ms \
                 (kv steady-state; {} prefills/{} kv_steps), utilization {:.1}%, \
                 kv/rescore tokens/s = {:.2}x",
                rs.seconds_per_step * 1e3,
                ks.seconds_per_step * 1e3,
                ks.prefills,
                kv.counters().get("infer/kv_steps"),
                ks.slot_utilization * 100.0,
                kv_tps / rescore_tps.max(1e-12),
            );
            if l >= 128 {
                assert!(
                    kv_tps >= rescore_tps,
                    "{model} n={n}: kv tokens/s ({kv_tps:.1}) must be >= \
                     rescore ({rescore_tps:.1}) at L={l}"
                );
            }
            // §Obs: request-latency percentiles (accumulated over every
            // bench iteration) for the BENCH_<pr>.json serve-p99 section
            append_row(
                "bench_results.jsonl",
                &Json::obj(vec![
                    ("group", Json::str("serve latency (obs)")),
                    ("name", Json::str(format!("{model} kv ({n} reqs x {gen} tok)"))),
                    ("ttft_ms_p50", Json::num(ks.ttft_ms_p50)),
                    ("ttft_ms_p99", Json::num(ks.ttft_ms_p99)),
                    ("latency_ms_p50", Json::num(ks.latency_ms_p50)),
                    ("latency_ms_p99", Json::num(ks.latency_ms_p99)),
                ]),
            );
        }
    }
    // §serve: open-loop Poisson traffic through the replica gateway
    // (1/2/4 replicas; rows feed the BENCH_8 gateway gate).
    poisson_gateway_bench(&arts, &device, quick);
    bench.write_jsonl("bench_results.jsonl").unwrap();
    device.shutdown();
}
