//! Property-based testing harness (proptest substitute — proptest is
//! unavailable in the offline registry).
//!
//! A [`Runner`] drives N random cases from a seeded [`Pcg64`]; on failure it
//! performs greedy shrinking via user-provided `shrink` steps (halving
//! integers, truncating vectors) and reports the minimal failing input's
//! seed so failures are reproducible.
//!
//! ```no_run
//! // (no_run: doctest binaries miss the xla_extension rpath in this image)
//! use t5x::testing::{Runner, Gen};
//! let mut r = Runner::new("sum_commutes", 200);
//! r.run(|g| {
//!     let a = g.usize_in(0, 1000);
//!     let b = g.usize_in(0, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::rng::Pcg64;

/// Random input generator handed to each property case.
pub struct Gen {
    rng: Pcg64,
    /// Log of drawn values for failure reporting.
    log: Vec<String>,
}

impl Gen {
    fn new(seed: u64) -> Gen {
        Gen { rng: Pcg64::new(seed), log: Vec::new() }
    }

    pub fn u64(&mut self) -> u64 {
        let v = self.rng.next_u64();
        self.log.push(format!("u64={v}"));
        v
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let v = lo + self.rng.next_below((hi - lo + 1) as u64) as usize;
        self.log.push(format!("usize={v}"));
        v
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let v = lo + self.rng.next_below((hi - lo + 1) as u64) as i64;
        self.log.push(format!("i64={v}"));
        v
    }

    pub fn f64_unit(&mut self) -> f64 {
        let v = self.rng.next_f64();
        self.log.push(format!("f64={v:.6}"));
        v
    }

    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        let v = lo + self.rng.next_f32() * (hi - lo);
        self.log.push(format!("f32={v:.6}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        self.usize_in(0, 1) == 1
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| lo + self.rng.next_f32() * (hi - lo)).collect()
    }

    pub fn vec_u32(&mut self, len: usize, below: u32) -> Vec<u32> {
        (0..len).map(|_| self.rng.next_below(below as u64) as u32).collect()
    }

    /// ASCII-ish random string (printable).
    pub fn string(&mut self, max_len: usize) -> String {
        let len = self.usize_in(0, max_len);
        (0..len)
            .map(|_| char::from(b' ' + self.rng.next_below(95) as u8))
            .collect()
    }

    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// Drives property cases. Each case gets a distinct deterministic seed.
pub struct Runner {
    name: String,
    cases: usize,
    base_seed: u64,
}

impl Runner {
    pub fn new(name: &str, cases: usize) -> Runner {
        // Allow global override for quicker CI sweeps.
        let cases = std::env::var("T5X_PROPTEST_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(cases);
        let base_seed = crate::util::rng::fnv1a64(name);
        Runner { name: name.to_string(), cases, base_seed }
    }

    /// Run the property; panics (with seed info) on the first failure.
    /// Closures capturing non-unwind-safe state are accepted: the harness
    /// aborts on first failure, so observing partially-mutated state is
    /// not a concern.
    pub fn run<F: Fn(&mut Gen)>(&mut self, prop: F) {
        for case in 0..self.cases {
            let seed = self.base_seed.wrapping_add(case as u64);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let mut g = Gen::new(seed);
                prop(&mut g);
            }));
            if let Err(payload) = result {
                // Re-run to capture the drawn values for the report.
                let mut g = Gen::new(seed);
                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                    || prop(&mut g),
                ));
                let drawn = g.log.join(", ");
                let msg = panic_message(&payload);
                panic!(
                    "property '{}' failed on case {case} (seed {seed})\n  drawn: [{drawn}]\n  cause: {msg}",
                    self.name
                );
            }
        }
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".into()
    }
}

/// Assert two f32 slices are elementwise close.
#[track_caller]
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol || (x.is_nan() && y.is_nan()),
            "allclose failed at index {i}: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut r = Runner::new("add_commutes", 50);
        r.run(|g| {
            let a = g.usize_in(0, 100);
            let b = g.usize_in(0, 100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always_fails' failed")]
    fn failing_property_reports_seed() {
        let mut r = Runner::new("always_fails", 5);
        r.run(|g| {
            let v = g.usize_in(0, 10);
            assert!(v > 100, "v themed too small: {v}");
        });
    }

    #[test]
    fn deterministic_across_runs() {
        use std::sync::Mutex;
        let first = Mutex::new(Vec::new());
        let mut r = Runner::new("det", 10);
        r.run(|g| {
            first.lock().unwrap().push(g.u64());
        });
        // Property runners with the same name draw the same values.
        let second = Mutex::new(Vec::new());
        let mut r2 = Runner::new("det", 10);
        r2.run(|g| {
            second.lock().unwrap().push(g.u64());
        });
        assert_eq!(*first.lock().unwrap(), *second.lock().unwrap());
    }

    #[test]
    fn allclose_passes_and_fails() {
        assert_allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-5);
        let r = std::panic::catch_unwind(|| {
            assert_allclose(&[1.0], &[2.0], 1e-5, 1e-5);
        });
        assert!(r.is_err());
    }
}
