"""AOT exporter: lower the L2/L1 computations to HLO text + manifest.json.

This is the only place Python touches the artifact directory; the Rust L3
binary is self-contained afterwards. Interchange is HLO *text* (NOT
``.serialize()``): jax >= 0.5 emits HloModuleProtos with 64-bit instruction
ids which xla_extension 0.5.1 (the version the published ``xla`` crate
binds) rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/load_hlo and its README.

Exports, per model config in ``model.CONFIGS``:
  <model>/train_step.hlo.txt   (params.., batch..) -> (loss_sum, weight_sum,
                                correct_sum, grads..)
  <model>/eval_step.hlo.txt    (params.., batch..) -> (loss_sum, weight_sum,
                                correct_sum)
  <model>/decode_logits.hlo.txt (params.., tokens..) -> (logits,)
plus:
  bench/{scan,unroll}_L{2,4,8}.hlo.txt   — Scalable T5 compile-time claim (E12)
  partdemo/ffn_{full,shard2,shard4}.hlo.txt — Megatron MLP sharding demo (E3)
  golden.json                   — loss/grad goldens for pattern-init params,
                                  cross-checked by Rust integration tests
  manifest.json                 — the artifact contract consumed by Rust
"""

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(path: str, text: str):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)


# ---------------------------------------------------------------------------
# Deterministic golden batch (formula mirrored by rust/src/model/golden.rs)
# ---------------------------------------------------------------------------


def golden_batch(cfg: M.ModelConfig):
    b, l, v = cfg.batch, cfg.seq_len, cfg.vocab
    tgt = np.fromfunction(
        lambda i, j: (i * 7919 + j * 104729 + 13) % (v - 2) + 2, (b, l), dtype=np.int64
    ).astype(np.int32)
    dec_in = np.zeros_like(tgt)
    dec_in[:, 1:] = tgt[:, :-1]
    weights = np.ones((b, l), np.float32)
    weights[0, -4:] = 0.0
    batch = {
        "decoder_input_tokens": dec_in,
        "decoder_target_tokens": tgt,
        "decoder_loss_weights": weights,
    }
    if cfg.arch == "encdec":
        batch["encoder_input_tokens"] = np.fromfunction(
            lambda i, j: (i * 6101 + j * 3571 + 29) % (v - 2) + 2, (b, l), dtype=np.int64
        ).astype(np.int32)
    return batch


def export_model(cfg: M.ModelConfig, out_dir: str, entry: dict):
    specs = M.param_specs(cfg)
    param_shapes = [jax.ShapeDtypeStruct(s[1], jnp.float32) for s in specs]
    bshapes = M.batch_shapes(cfg)
    bfeat = M.batch_feature_names(cfg)

    train_fn, _ = M.train_step_fn(cfg)
    eval_fn, _ = M.eval_step_fn(cfg)
    dec_fn, _ = M.decode_logits_fn(cfg)

    t0 = time.time()
    train_args = param_shapes + [bshapes[f] for f in bfeat]
    _write(
        f"{out_dir}/{cfg.name}/train_step.hlo.txt",
        to_hlo_text(jax.jit(train_fn).lower(*train_args)),
    )
    _write(
        f"{out_dir}/{cfg.name}/eval_step.hlo.txt",
        to_hlo_text(jax.jit(eval_fn).lower(*train_args)),
    )
    tok_shapes = [bshapes[f] for f in bfeat if f.endswith("input_tokens")]
    _write(
        f"{out_dir}/{cfg.name}/decode_logits.hlo.txt",
        to_hlo_text(jax.jit(dec_fn).lower(*(param_shapes + tok_shapes))),
    )
    print(f"  {cfg.name}: exported in {time.time() - t0:.1f}s")

    entry[cfg.name] = {
        "arch": cfg.arch,
        "config": {
            k: v
            for k, v in dataclasses.asdict(cfg).items()
            if isinstance(v, (int, float, str, bool))
        },
        "params": [
            {
                "name": n,
                "shape": list(shape),
                "dtype": "f32",
                "logical_axes": list(axes),
                "init": init,
            }
            for (n, shape, axes, init) in specs
        ],
        "batch_features": [
            {
                "name": f,
                "shape": list(bshapes[f].shape),
                "dtype": "i32" if bshapes[f].dtype == jnp.int32 else "f32",
            }
            for f in bfeat
        ],
        "entrypoints": {
            "train_step": {
                "hlo": f"{cfg.name}/train_step.hlo.txt",
                "outputs": ["loss_sum", "weight_sum", "correct_sum"]
                + [f"grad:{s[0]}" for s in specs],
            },
            "eval_step": {
                "hlo": f"{cfg.name}/eval_step.hlo.txt",
                "outputs": ["loss_sum", "weight_sum", "correct_sum"],
            },
            "decode_logits": {
                "hlo": f"{cfg.name}/decode_logits.hlo.txt",
                "inputs": [f for f in bfeat if f.endswith("input_tokens")],
                "outputs": ["logits"],
            },
        },
    }


def export_golden(cfg: M.ModelConfig, goldens: dict):
    """Loss + grad-norm goldens for pattern-init params on the golden batch."""
    params = M.pattern_params(cfg)
    batch = golden_batch(cfg)
    train_fn, names = M.train_step_fn(cfg)
    args = [params[n] for n in names] + [
        jnp.asarray(batch[f]) for f in M.batch_feature_names(cfg)
    ]
    outs = jax.jit(train_fn)(*args)
    loss_sum, weight_sum, correct_sum = (float(x) for x in outs[:3])
    grad_norms = {
        n: float(jnp.linalg.norm(g.astype(jnp.float32)))
        for n, g in zip(names, outs[3:])
    }
    goldens[cfg.name] = {
        "init": "pattern:seed=0:scale=0.05",
        "loss_sum": loss_sum,
        "weight_sum": weight_sum,
        "correct_sum": correct_sum,
        "grad_norms": grad_norms,
    }
    print(
        f"  golden {cfg.name}: loss_sum={loss_sum:.4f} weight_sum={weight_sum}"
        f" correct_sum={correct_sum}"
    )


def export_bench(out_dir: str, manifest: dict):
    """Scan vs unrolled lowering at several depths (Scalable T5, E12)."""
    bench = {}
    for depth in (2, 4, 8):
        cfg = dataclasses.replace(
            M.CONFIGS["t5-micro-dec"], num_layers=depth, use_pallas=False
        )
        d, jkv, ff = cfg.d_model, cfg.joined_kv, cfg.d_ff
        stacked = [
            jax.ShapeDtypeStruct((cfg.vocab, d), jnp.float32),  # embed
            jax.ShapeDtypeStruct((cfg.relpos_buckets, cfg.num_heads), jnp.float32),
            jax.ShapeDtypeStruct((depth, d), jnp.float32),  # norm1
            jax.ShapeDtypeStruct((depth, d, jkv), jnp.float32),  # wq
            jax.ShapeDtypeStruct((depth, d, jkv), jnp.float32),  # wk
            jax.ShapeDtypeStruct((depth, d, jkv), jnp.float32),  # wv
            jax.ShapeDtypeStruct((depth, jkv, d), jnp.float32),  # wo
            jax.ShapeDtypeStruct((depth, d), jnp.float32),  # norm2
            jax.ShapeDtypeStruct((depth, d, ff), jnp.float32),  # wi0
            jax.ShapeDtypeStruct((depth, d, ff), jnp.float32),  # wi1
            jax.ShapeDtypeStruct((depth, ff, d), jnp.float32),  # wo2
            jax.ShapeDtypeStruct((d,), jnp.float32),  # final norm
            jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32),
            jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32),
            jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.float32),
        ]
        for kind, fn in (
            ("scan", M.scan_decoder_loss_fn(cfg)),
            ("unroll", M.unrolled_decoder_loss_fn(cfg)),
        ):
            grad_fn = jax.value_and_grad(fn, argnums=tuple(range(12)))
            path = f"bench/{kind}_L{depth}.hlo.txt"
            _write(f"{out_dir}/{path}", to_hlo_text(jax.jit(grad_fn).lower(*stacked)))
            bench[f"{kind}_L{depth}"] = path
        print(f"  bench depth {depth}: scan + unroll exported")
    manifest["bench"] = bench


def export_partdemo(out_dir: str, manifest: dict):
    """Megatron-style MLP sharding demo HLOs (E3): column-parallel w1,
    row-parallel w2; rust all-reduces the partial outputs."""
    mdim, k, f = 64, 256, 1024

    def ffn(x, w1, w2):
        return (jax.nn.gelu(x @ w1, approximate=True) @ w2,)

    demo = {"m": mdim, "k": k, "f": f, "hlos": {}}
    for n in (1, 2, 4):
        fs = f // n
        args = [
            jax.ShapeDtypeStruct((mdim, k), jnp.float32),
            jax.ShapeDtypeStruct((k, fs), jnp.float32),
            jax.ShapeDtypeStruct((fs, k), jnp.float32),
        ]
        name = "ffn_full" if n == 1 else f"ffn_shard{n}"
        path = f"partdemo/{name}.hlo.txt"
        _write(f"{out_dir}/{path}", to_hlo_text(jax.jit(ffn).lower(*args)))
        demo["hlos"][name] = path
    manifest["partdemo"] = demo
    print("  partdemo exported")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--models",
        default="t5-nano-dec,t5-nano-encdec,t5-micro-dec,t5-micro-encdec,"
        "t5-small-dec,t5-100m-dec",
    )
    args = ap.parse_args()
    out = args.out
    manifest = {"format_version": 1, "models": {}}

    t0 = time.time()
    for name in args.models.split(","):
        export_model(M.CONFIGS[name], out, manifest["models"])
    export_bench(out, manifest)
    export_partdemo(out, manifest)

    goldens = {}
    for name in ("t5-nano-dec", "t5-nano-encdec"):
        if name in manifest["models"]:
            export_golden(M.CONFIGS[name], goldens)
    _write(f"{out}/golden.json", json.dumps(goldens, indent=1))
    _write(f"{out}/manifest.json", json.dumps(manifest, indent=1))
    print(f"artifacts written to {out} in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
