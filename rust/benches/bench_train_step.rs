//! E16: end-to-end train-step throughput — tokens/sec across model sizes
//! and host counts, 1D vs 2D, gather vs block execution, on the full
//! Rust-coordinated path (infeed-synthetic -> PJRT fwd/bwd -> ring
//! collectives -> optimizer).

use t5x::bench::Bench;
use t5x::optim::{OptimizerKind, Schedule};
use t5x::partitioning::{ExecMode, Mesh, ParamStrategy};
use t5x::runtime::{Artifacts, DeviceHandle};
use t5x::trainer::{BatchSource, Trainer, TrainerConfig};
use t5x::util::json::Json;

/// Append one extra JSONL row to the shared bench log (rows the harness
/// doesn't model, e.g. the per-phase step breakdown for BENCH_<pr>.json).
fn append_row(path: &str, row: &Json) {
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .expect("open bench log");
    writeln!(f, "{row}").expect("append bench row");
}

fn main() {
    let arts = Artifacts::load_default().expect("make artifacts first");
    let device = DeviceHandle::spawn().unwrap();
    let mut bench = Bench::new("train step (E16)");
    let models: &[&str] = if bench.is_quick() {
        &["t5-nano-dec"]
    } else {
        &["t5-nano-dec", "t5-micro-dec", "t5-small-dec"]
    };
    let steps: u64 = if bench.is_quick() { 2 } else { 4 };

    for model in models {
        let m = arts.model(model).unwrap();
        for (mesh, strategy, exec_mode) in [
            (Mesh::new(1, 1), ParamStrategy::OneD, ExecMode::Gather),
            (Mesh::new(2, 1), ParamStrategy::OneD, ExecMode::Gather),
            (Mesh::new(2, 1), ParamStrategy::TwoD, ExecMode::Gather),
            (Mesh::new(2, 2), ParamStrategy::TwoD, ExecMode::Gather),
            // gather-vs-block head-to-head on model-parallel meshes
            (Mesh::new(1, 2), ParamStrategy::OneD, ExecMode::Gather),
            (Mesh::new(1, 2), ParamStrategy::OneD, ExecMode::Block),
            (Mesh::new(2, 2), ParamStrategy::TwoD, ExecMode::Block),
        ] {
            if exec_mode == ExecMode::Block && !m.supports_block_exec(mesh.model) {
                continue; // artifacts carry no block contract for this model
            }
            let cfg = TrainerConfig {
                model: model.to_string(),
                mesh,
                strategy,
                optimizer: OptimizerKind::adam(),
                schedule: Schedule::Constant(1e-4),
                steps,
                seed: 0,
                log_every: 1000,
                checkpoint_every: None,
                checkpoint_dir: None,
                grad_clip_norm: None,
                weight_decay: None,
                exec_mode,
                trace_out: None,
                profile_steps: None,
                microbatches: 1,
                overlap: false,
                infeed_depth: 2,
            };
            let cfg_traced = cfg.clone();
            let trainer = Trainer::new(&arts, &device, cfg).unwrap();
            let tokens = (m.tokens_per_step() * mesh.data * steps as usize) as f64;
            bench.measure_with_throughput(
                &format!("{model} mesh={mesh} {strategy:?} {exec_mode} ({steps} steps)"),
                Some((tokens, "tok")),
                || {
                    let s = trainer.train(&BatchSource::Synthetic { seed: 1 }).unwrap();
                    assert!(s.final_loss().is_finite());
                },
            );
            // §Perf: phase breakdown + per-host peak param memory
            let rows = trainer.timing.rows();
            let total: f64 = rows.iter().map(|(_, s)| s).sum();
            let pct: Vec<String> = rows
                .iter()
                .map(|(n, s)| format!("{n} {:.0}%", 100.0 * s / total.max(1e-9)))
                .collect();
            println!("      breakdown: {}", pct.join(", "));
            println!(
                "      peak param/grad tensor: {} floats ({} mode)",
                trainer.peak_param_floats(),
                trainer.exec_mode
            );
            // §Obs: same case with an armed tracer (spans recorded, no
            // export) — the CI gate holds traced tok/s within a few % of
            // the untraced row above.
            let traced = Trainer::new(&arts, &device, cfg_traced)
                .unwrap()
                .with_tracer(t5x::obs::Tracer::new());
            bench.measure_with_throughput(
                &format!(
                    "{model} mesh={mesh} {strategy:?} {exec_mode} traced ({steps} steps)"
                ),
                Some((tokens, "tok")),
                || {
                    let s = traced.train(&BatchSource::Synthetic { seed: 1 }).unwrap();
                    assert!(s.final_loss().is_finite());
                },
            );
            // step-phase ms breakdown (rank-0 wall-clock deltas, averaged
            // over every traced step) for the BENCH_<pr>.json trajectory
            let ph = &traced.phase_hist;
            append_row(
                "bench_results.jsonl",
                &Json::obj(vec![
                    ("group", Json::str("train phase breakdown (obs)")),
                    (
                        "name",
                        Json::str(format!("{model} mesh={mesh} {strategy:?} {exec_mode}")),
                    ),
                    ("infeed_ms", Json::num(ph.infeed.mean_ms())),
                    ("execute_ms", Json::num(ph.execute.mean_ms())),
                    ("coll_data_ms", Json::num(ph.collectives_data.mean_ms())),
                    ("coll_model_ms", Json::num(ph.collectives_model.mean_ms())),
                    ("optimizer_ms", Json::num(ph.optimizer.mean_ms())),
                    ("step_ms_p50", Json::num(ph.step_ms.p50())),
                    ("step_ms_p99", Json::num(ph.step_ms.p99())),
                    ("steps", Json::num(ph.step_ms.count() as f64)),
                ]),
            );
        }
    }

    // §Overlap: serial vs overlapped comm at microbatches 1/2/4 on
    // multi-rank meshes. The two modes are bit-identical in numerics; the
    // only difference is whether microbatch j's data-axis gradient reduce
    // rides under microbatch j+1's forward/backward on the comm lane.
    let overlap_meshes: &[(Mesh, ParamStrategy)] = if bench.is_quick() {
        &[(Mesh::new(2, 1), ParamStrategy::OneD)]
    } else {
        &[
            (Mesh::new(2, 1), ParamStrategy::OneD),
            (Mesh::new(2, 2), ParamStrategy::TwoD),
        ]
    };
    for model in models {
        let m = arts.model(model).unwrap();
        for &(mesh, strategy) in overlap_meshes {
            for k in [1usize, 2, 4] {
                // (tok/s, per-step ms, exposed-comm µs, overlapped-comm µs)
                let mut rows: Vec<(f64, f64, u64, u64)> = Vec::new();
                for overlap in [false, true] {
                    let cfg = TrainerConfig {
                        model: model.to_string(),
                        mesh,
                        strategy,
                        optimizer: OptimizerKind::adam(),
                        schedule: Schedule::Constant(1e-4),
                        steps,
                        seed: 0,
                        log_every: 1000,
                        checkpoint_every: None,
                        checkpoint_dir: None,
                        grad_clip_norm: None,
                        weight_decay: None,
                        exec_mode: ExecMode::Gather,
                        trace_out: None,
                        profile_steps: None,
                        microbatches: k,
                        overlap,
                        infeed_depth: 2,
                    };
                    let trainer = Trainer::new(&arts, &device, cfg).unwrap();
                    let tokens =
                        (m.tokens_per_step() * mesh.data * steps as usize * k) as f64;
                    let mode = if overlap { "overlap" } else { "serial" };
                    let mut comm = (0u64, 0u64);
                    let meas = bench.measure_with_throughput(
                        &format!("{model} mesh={mesh} mb={k} {mode} ({steps} steps)"),
                        Some((tokens, "tok")),
                        || {
                            let s = trainer
                                .train(&BatchSource::Synthetic { seed: 1 })
                                .unwrap();
                            assert!(s.final_loss().is_finite());
                            comm = (s.exposed_comm_micros, s.overlapped_comm_micros);
                        },
                    );
                    rows.push((
                        meas.throughput_per_sec().unwrap_or(0.0),
                        meas.median_s * 1e3 / steps as f64,
                        comm.0,
                        comm.1,
                    ));
                }
                let (serial_tok_s, serial_step_ms, serial_exposed, _) = rows[0];
                let (overlap_tok_s, overlap_step_ms, overlap_exposed, overlapped) =
                    rows[1];
                println!(
                    "      mb={k}: exposed comm {:.2} -> {:.2} ms, overlapped {:.2} ms",
                    serial_exposed as f64 / 1e3,
                    overlap_exposed as f64 / 1e3,
                    overlapped as f64 / 1e3,
                );
                append_row(
                    "bench_results.jsonl",
                    &Json::obj(vec![
                        ("group", Json::str("train overlap (serial vs overlapped)")),
                        ("name", Json::str(format!("{model} mesh={mesh} mb={k}"))),
                        ("microbatches", Json::num(k as f64)),
                        ("serial_tok_s", Json::num(serial_tok_s)),
                        ("overlap_tok_s", Json::num(overlap_tok_s)),
                        ("serial_step_ms", Json::num(serial_step_ms)),
                        ("overlap_step_ms", Json::num(overlap_step_ms)),
                        (
                            "serial_exposed_comm_ms",
                            Json::num(serial_exposed as f64 / 1e3),
                        ),
                        (
                            "overlap_exposed_comm_ms",
                            Json::num(overlap_exposed as f64 / 1e3),
                        ),
                        ("overlapped_comm_ms", Json::num(overlapped as f64 / 1e3)),
                    ]),
                );
            }
        }
    }

    // §Supervisor: plain vs fault-free supervised run (ISSUE 10). A
    // supervised run carries the restart loop, the recovery counters,
    // the disarmed fault hooks on every step, and an armed 60 s ring
    // deadline — all of which must be free when nothing fails. The CI
    // gate holds supervised tok/s on the plain trainer's line.
    {
        use t5x::trainer::supervisor::{Supervisor, SupervisorConfig};
        for model in models {
            let m = arts.model(model).unwrap();
            for (mesh, strategy) in [
                (Mesh::new(1, 1), ParamStrategy::OneD),
                (Mesh::new(2, 1), ParamStrategy::OneD),
            ] {
                let cfg = TrainerConfig {
                    model: model.to_string(),
                    mesh,
                    strategy,
                    optimizer: OptimizerKind::adam(),
                    schedule: Schedule::Constant(1e-4),
                    steps,
                    seed: 0,
                    log_every: 1000,
                    checkpoint_every: None,
                    checkpoint_dir: None,
                    grad_clip_norm: None,
                    weight_decay: None,
                    exec_mode: ExecMode::Gather,
                    trace_out: None,
                    profile_steps: None,
                    microbatches: 1,
                    overlap: false,
                    infeed_depth: 2,
                };
                let tokens = (m.tokens_per_step() * mesh.data * steps as usize) as f64;
                let plain = Trainer::new(&arts, &device, cfg.clone()).unwrap();
                let plain_meas = bench.measure_with_throughput(
                    &format!("{model} mesh={mesh} {strategy:?} plain ({steps} steps)"),
                    Some((tokens, "tok")),
                    || {
                        let s = plain.train(&BatchSource::Synthetic { seed: 1 }).unwrap();
                        assert!(s.final_loss().is_finite());
                    },
                );
                let sup = Supervisor::new(
                    &arts,
                    &device,
                    cfg,
                    SupervisorConfig {
                        max_restarts: 3,
                        backoff_ms: 1,
                        comm_deadline_ms: Some(60_000),
                        resume: false,
                    },
                );
                let sup_meas = bench.measure_with_throughput(
                    &format!("{model} mesh={mesh} {strategy:?} supervised ({steps} steps)"),
                    Some((tokens, "tok")),
                    || {
                        let run = sup
                            .run(
                                |_trainer| Ok(BatchSource::Synthetic { seed: 1 }),
                                |t, _attempt| t,
                            )
                            .unwrap();
                        assert_eq!(run.restarts, 0);
                        assert!(run.summary.final_loss().is_finite());
                    },
                );
                append_row(
                    "bench_results.jsonl",
                    &Json::obj(vec![
                        ("group", Json::str("train supervisor (fault-free)")),
                        ("name", Json::str(format!("{model} mesh={mesh} {strategy:?}"))),
                        (
                            "plain_tok_s",
                            Json::num(plain_meas.throughput_per_sec().unwrap_or(0.0)),
                        ),
                        (
                            "supervised_tok_s",
                            Json::num(sup_meas.throughput_per_sec().unwrap_or(0.0)),
                        ),
                    ]),
                );
            }
        }
    }

    // the 100M config: a few steps to prove the path + measure step time
    if !bench.is_quick() {
        let model = "t5-100m-dec";
        let m = arts.model(model).unwrap();
        let cfg = TrainerConfig {
            model: model.into(),
            mesh: Mesh::new(1, 1),
            strategy: ParamStrategy::OneD,
            optimizer: OptimizerKind::adam(),
            schedule: Schedule::Constant(1e-4),
            steps: 1,
            seed: 0,
            log_every: 1000,
            checkpoint_every: None,
            checkpoint_dir: None,
            grad_clip_norm: None,
            weight_decay: None,
            exec_mode: ExecMode::Gather,
            trace_out: None,
            profile_steps: None,
            microbatches: 1,
            overlap: false,
            infeed_depth: 2,
        };
        let trainer = Trainer::new(&arts, &device, cfg).unwrap();
        let tokens = m.tokens_per_step() as f64;
        bench.measure_with_throughput(
            &format!("{model} mesh=1x1 OneD (1 step)"),
            Some((tokens, "tok")),
            || {
                let s = trainer.train(&BatchSource::Synthetic { seed: 1 }).unwrap();
                assert!(s.final_loss().is_finite());
            },
        );
    }
    bench.write_jsonl("bench_results.jsonl").unwrap();
    device.shutdown();
}
