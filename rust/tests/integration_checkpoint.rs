//! Integration: checkpointing (E11) — trainer save/restore across topology
//! changes (read-with-resharding), legacy conversion, async save.

use t5x::checkpoint::{legacy, CheckpointManager};
use t5x::optim::{OptimizerKind, Schedule};
use t5x::partitioning::{Mesh, ParamStrategy};
use t5x::runtime::{Artifacts, DeviceHandle};
use t5x::trainer::{BatchSource, Trainer, TrainerConfig};

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("ckpt_int_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Save with 2 hosts / ZeRO, restore into 4 hosts / ZeRO and 1 host / 1D:
/// the topology-change restore the paper gets from TensorStore slicing.
#[test]
fn restore_across_topologies() {
    let arts = Artifacts::load_default().unwrap();
    let device = DeviceHandle::spawn().unwrap();
    let dir = tmpdir("topo");

    let mut cfg = TrainerConfig::quick("t5-nano-dec", 4);
    cfg.mesh = Mesh::new(2, 1);
    cfg.strategy = ParamStrategy::TwoD;
    cfg.schedule = Schedule::Constant(1e-3);
    cfg.checkpoint_every = Some(4);
    cfg.checkpoint_dir = Some(dir.clone());
    let t = Trainer::new(&arts, &device, cfg.clone()).unwrap();
    t.train(&BatchSource::Synthetic { seed: 3 }).unwrap();
    let saved_params = t.params();

    // 4-host ZeRO restore
    let mut cfg4 = cfg.clone();
    cfg4.mesh = Mesh::new(4, 1);
    cfg4.checkpoint_every = None;
    cfg4.checkpoint_dir = None;
    let mut t4 = Trainer::new(&arts, &device, cfg4).unwrap();
    assert_eq!(t4.restore_latest(&dir).unwrap(), 4);
    assert_eq!(t4.params(), saved_params);

    // single-host 1D restore
    let mut cfg1 = cfg;
    cfg1.mesh = Mesh::new(1, 1);
    cfg1.strategy = ParamStrategy::OneD;
    cfg1.checkpoint_every = None;
    cfg1.checkpoint_dir = None;
    let mut t1 = Trainer::new(&arts, &device, cfg1).unwrap();
    assert_eq!(t1.restore_latest(&dir).unwrap(), 4);
    assert_eq!(t1.params(), saved_params);

    // both restored trainers continue to train
    let s = t4.train(&BatchSource::Synthetic { seed: 3 }).unwrap();
    assert_eq!(s.history.first().unwrap().step, 4);
    std::fs::remove_dir_all(&dir).ok();
    device.shutdown();
}

/// Sliced restore: pull a single host's row-range of a parameter without
/// reading the rest (the TensorStore capability).
#[test]
fn sliced_param_reads() {
    let arts = Artifacts::load_default().unwrap();
    let m = arts.model("t5-nano-dec").unwrap();
    let dir = tmpdir("slice");
    let mgr = CheckpointManager::new(&dir);
    let params = t5x::model::pattern_params(m, 0);
    mgr.save(1, &params, &Vec::new()).unwrap();

    let emb = &params["token_embed"];
    let rows = emb.shape[0];
    let half = mgr
        .restore_param_slice(1, "token_embed", rows / 2, rows / 2)
        .unwrap();
    let expect = emb.slice_axis(0, rows / 2, rows / 2);
    assert_eq!(half, expect.as_f32());
    std::fs::remove_dir_all(&dir).ok();
}

/// Legacy format conversion (§2.3): legacy -> native roundtrips parameters
/// and the converted checkpoint loads into a trainer.
#[test]
fn legacy_convert_then_train() {
    let arts = Artifacts::load_default().unwrap();
    let m = arts.model("t5-nano-dec").unwrap();
    let dir = tmpdir("legacy");
    std::fs::create_dir_all(&dir).unwrap();
    let params = t5x::model::init_params(m, 9);
    let legacy_path = dir.join("legacy.ckpt");
    legacy::save_legacy(&legacy_path, &params).unwrap();

    let mgr = CheckpointManager::new(dir.join("native"));
    let n = legacy::convert_to_native(&legacy_path, &mgr, 0).unwrap();
    assert_eq!(n, m.params.len());

    let device = DeviceHandle::spawn().unwrap();
    let mut cfg = TrainerConfig::quick("t5-nano-dec", 2);
    cfg.optimizer = OptimizerKind::adam();
    let mut t = Trainer::new(&arts, &device, cfg).unwrap();
    t.restore_latest(&dir.join("native")).unwrap();
    assert_eq!(t.params(), params);
    let s = t.train(&BatchSource::Synthetic { seed: 0 }).unwrap();
    assert_eq!(s.history.len(), 2);
    std::fs::remove_dir_all(&dir).ok();
    device.shutdown();
}

/// Async checkpointing does not corrupt concurrent training state.
#[test]
fn async_save_snapshot_isolated() {
    let arts = Artifacts::load_default().unwrap();
    let m = arts.model("t5-nano-dec").unwrap();
    let dir = tmpdir("async");
    let mgr = CheckpointManager::new(&dir);
    let params = t5x::model::init_params(m, 4);
    let snapshot = params.clone();
    let handle = mgr.save_async(10, snapshot, Vec::new(), None);
    // mutate "live" params while the save runs — the snapshot must win
    handle.join().unwrap().unwrap();
    let (restored, _) = mgr.restore(10).unwrap();
    assert_eq!(restored, params);
    std::fs::remove_dir_all(&dir).ok();
}
