//! Vocabularies: the SentencePiece substitute.
//!
//! * [`ByteVocabulary`] — ByT5-style byte-level ids (paper §4 lists ByT5).
//! * [`BpeVocabulary`] — a trainable byte-pair-encoding subword vocabulary,
//!   standing in for SentencePiece (unavailable offline). Trained once on
//!   the synthetic corpus by the cache job / examples.
//!
//! Shared id conventions (t5x defaults):
//!   0 = PAD, 1 = EOS, 2 = UNK; the top `extra_ids` ids are the T5 sentinel
//!   tokens used by span corruption (`<extra_id_0>` = vocab_size - 1, ...).

use std::collections::{BTreeMap, HashMap};

pub const PAD_ID: i32 = 0;
pub const EOS_ID: i32 = 1;
pub const UNK_ID: i32 = 2;

/// Common vocabulary interface (seqio.Vocabulary).
pub trait Vocabulary: Send + Sync {
    /// Total size including special and sentinel ids.
    fn vocab_size(&self) -> usize;
    /// Number of reserved sentinel (extra) ids at the top of the range.
    fn extra_ids(&self) -> usize;
    fn encode(&self, text: &str) -> Vec<i32>;
    fn decode(&self, ids: &[i32]) -> String;

    /// id of sentinel k (k=0 is the highest id), following T5 convention.
    fn sentinel(&self, k: usize) -> i32 {
        assert!(k < self.extra_ids(), "sentinel {k} out of range");
        (self.vocab_size() - 1 - k) as i32
    }

    fn is_sentinel(&self, id: i32) -> bool {
        let lo = self.vocab_size() - self.extra_ids();
        (id as usize) >= lo && (id as usize) < self.vocab_size()
    }
}

// ---------------------------------------------------------------------------
// Byte vocabulary
// ---------------------------------------------------------------------------

/// ByT5-style byte vocabulary: id = byte + 3.
pub struct ByteVocabulary {
    extra: usize,
}

impl ByteVocabulary {
    pub fn new(extra_ids: usize) -> Self {
        Self { extra: extra_ids }
    }
}

impl Vocabulary for ByteVocabulary {
    fn vocab_size(&self) -> usize {
        3 + 256 + self.extra
    }

    fn extra_ids(&self) -> usize {
        self.extra
    }

    fn encode(&self, text: &str) -> Vec<i32> {
        text.bytes().map(|b| b as i32 + 3).collect()
    }

    fn decode(&self, ids: &[i32]) -> String {
        let bytes: Vec<u8> = ids
            .iter()
            .filter_map(|&id| {
                if (3..259).contains(&id) {
                    Some((id - 3) as u8)
                } else {
                    None // drop pad/eos/unk/sentinels
                }
            })
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

// ---------------------------------------------------------------------------
// BPE vocabulary
// ---------------------------------------------------------------------------

/// Trainable byte-pair-encoding vocabulary over whitespace-split words.
/// Words are terminated with `</w>`; unknown characters map to UNK.
pub struct BpeVocabulary {
    /// token string -> id
    token_to_id: HashMap<String, i32>,
    id_to_token: Vec<String>,
    /// merge rules in priority order: (left, right) -> rank
    merges: HashMap<(String, String), usize>,
    extra: usize,
}

const END: &str = "</w>";

impl BpeVocabulary {
    /// Train on a corpus to approximately `target_size` total ids
    /// (including 3 specials and `extra_ids` sentinels).
    pub fn train(corpus: impl Iterator<Item = String>, target_size: usize, extra_ids: usize) -> Self {
        // 1. word frequencies
        let mut word_freq: BTreeMap<String, u64> = BTreeMap::new();
        for line in corpus {
            for w in line.split_whitespace() {
                *word_freq.entry(w.to_string()).or_default() += 1;
            }
        }
        // 2. initial symbol sequences: chars + </w>
        let mut words: Vec<(Vec<String>, u64)> = word_freq
            .iter()
            .map(|(w, f)| {
                let mut syms: Vec<String> = w.chars().map(|c| c.to_string()).collect();
                syms.push(END.to_string());
                (syms, *f)
            })
            .collect();
        // alphabet
        let mut tokens: Vec<String> = {
            let mut set: BTreeMap<String, ()> = BTreeMap::new();
            set.insert(END.to_string(), ());
            for (syms, _) in &words {
                for s in syms {
                    set.insert(s.clone(), ());
                }
            }
            set.into_keys().collect()
        };
        let specials = 3;
        let budget = target_size.saturating_sub(specials + extra_ids);
        let mut merges: Vec<(String, String)> = Vec::new();
        // 3. merge loop
        while tokens.len() < budget {
            let mut pair_freq: HashMap<(String, String), u64> = HashMap::new();
            for (syms, f) in &words {
                for win in syms.windows(2) {
                    *pair_freq
                        .entry((win[0].clone(), win[1].clone()))
                        .or_default() += f;
                }
            }
            // deterministic tie-break: highest freq, then lexicographic
            let best = pair_freq.into_iter().max_by(|a, b| {
                a.1.cmp(&b.1).then_with(|| b.0.cmp(&a.0))
            });
            let Some(((l, r), f)) = best else { break };
            if f < 2 {
                break; // nothing frequent left to merge
            }
            let merged = format!("{l}{r}");
            for (syms, _) in &mut words {
                let mut i = 0;
                while i + 1 < syms.len() {
                    if syms[i] == l && syms[i + 1] == r {
                        syms[i] = merged.clone();
                        syms.remove(i + 1);
                    } else {
                        i += 1;
                    }
                }
            }
            tokens.push(merged);
            merges.push((l, r));
        }
        // 4. id assignment: specials, then tokens (sorted for determinism),
        //    sentinels implicitly at the top.
        tokens.sort();
        tokens.dedup();
        let mut token_to_id = HashMap::new();
        let mut id_to_token = vec!["<pad>".to_string(), "<eos>".to_string(), "<unk>".to_string()];
        for t in &tokens {
            token_to_id.insert(t.clone(), id_to_token.len() as i32);
            id_to_token.push(t.clone());
        }
        let merge_ranks = merges
            .into_iter()
            .enumerate()
            .map(|(i, p)| (p, i))
            .collect();
        Self { token_to_id, id_to_token, merges: merge_ranks, extra: extra_ids }
    }

    fn encode_word(&self, word: &str) -> Vec<i32> {
        let mut syms: Vec<String> = word.chars().map(|c| c.to_string()).collect();
        syms.push(END.to_string());
        // apply merges in rank order until none apply
        loop {
            let mut best: Option<(usize, usize)> = None; // (rank, position)
            for i in 0..syms.len().saturating_sub(1) {
                if let Some(&rank) = self
                    .merges
                    .get(&(syms[i].clone(), syms[i + 1].clone()))
                {
                    if best.map(|(r, _)| rank < r).unwrap_or(true) {
                        best = Some((rank, i));
                    }
                }
            }
            match best {
                Some((_, i)) => {
                    let merged = format!("{}{}", syms[i], syms[i + 1]);
                    syms[i] = merged;
                    syms.remove(i + 1);
                }
                None => break,
            }
        }
        syms.iter()
            .map(|s| self.token_to_id.get(s).copied().unwrap_or(UNK_ID))
            .collect()
    }
}

impl Vocabulary for BpeVocabulary {
    fn vocab_size(&self) -> usize {
        self.id_to_token.len() + self.extra
    }

    fn extra_ids(&self) -> usize {
        self.extra
    }

    fn encode(&self, text: &str) -> Vec<i32> {
        let mut out = Vec::new();
        for w in text.split_whitespace() {
            out.extend(self.encode_word(w));
        }
        out
    }

    fn decode(&self, ids: &[i32]) -> String {
        let mut out = String::new();
        for &id in ids {
            let idx = id as usize;
            if id == PAD_ID || id == EOS_ID || self.is_sentinel(id) {
                continue;
            }
            if let Some(tok) = self.id_to_token.get(idx) {
                if let Some(stripped) = tok.strip_suffix(END) {
                    out.push_str(stripped);
                    out.push(' ');
                } else if tok == "<unk>" {
                    out.push('\u{fffd}');
                } else {
                    out.push_str(tok);
                }
            }
        }
        out.trim_end().to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_vocab_roundtrip() {
        let v = ByteVocabulary::new(16);
        let ids = v.encode("hello");
        assert_eq!(v.decode(&ids), "hello");
        assert_eq!(v.vocab_size(), 3 + 256 + 16);
        assert_eq!(v.sentinel(0), (v.vocab_size() - 1) as i32);
        assert!(v.is_sentinel(v.sentinel(3)));
        assert!(!v.is_sentinel(100));
    }

    #[test]
    fn byte_decode_skips_specials() {
        let v = ByteVocabulary::new(4);
        let mut ids = v.encode("ab");
        ids.push(EOS_ID);
        ids.push(PAD_ID);
        ids.push(v.sentinel(0));
        assert_eq!(v.decode(&ids), "ab");
    }

    fn corpus() -> Vec<String> {
        let base = [
            "the quick brown fox jumps over the lazy dog",
            "the dog barks at the quick fox",
            "lazy brown dogs and quick red foxes",
            "over and over the fox jumps",
        ];
        (0..50).map(|i| base[i % base.len()].to_string()).collect()
    }

    #[test]
    fn bpe_trains_and_roundtrips() {
        let v = BpeVocabulary::train(corpus().into_iter(), 200, 16);
        assert!(v.vocab_size() <= 200 + 16);
        let text = "the quick fox jumps";
        let ids = v.encode(text);
        assert!(!ids.is_empty());
        assert_eq!(v.decode(&ids), text);
        // frequent words should compress below character-level length
        assert!(ids.len() < text.len());
    }

    #[test]
    fn bpe_unknown_chars_map_to_unk() {
        let v = BpeVocabulary::train(corpus().into_iter(), 100, 4);
        let ids = v.encode("zebra ξ");
        assert!(ids.contains(&UNK_ID));
    }

    #[test]
    fn bpe_deterministic_training() {
        let v1 = BpeVocabulary::train(corpus().into_iter(), 150, 8);
        let v2 = BpeVocabulary::train(corpus().into_iter(), 150, 8);
        assert_eq!(v1.encode("the quick brown fox"), v2.encode("the quick brown fox"));
        assert_eq!(v1.vocab_size(), v2.vocab_size());
    }
}
