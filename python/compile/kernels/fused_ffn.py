"""L1 Pallas kernel: fused gated-GeLU feed-forward block (T5.1.1 MLP).

Computes ``y = (gelu(x @ wi_0) * (x @ wi_1)) @ wo`` in one kernel so the
[M, d_ff] hidden activation never round-trips to HBM.

TPU-oriented design (DESIGN.md §Hardware-Adaptation):
  * grid = (M / block_m, d_ff / block_f): the hidden dimension is tiled and
    partial products are accumulated into the output tile, so VMEM holds
    only [block_m, d_ff_block] of the gate/linear activations at a time.
  * the inner matmuls are shaped for the 128x128 MXU when the problem is
    large enough (_pick_block clamps for small test shapes).
  * executed with ``interpret=True`` for CPU-PJRT (see attention.py).

Backward uses jax.custom_vjp with the ``ref.gated_ffn_ref`` VJP: exact,
and keeps the exported train-step HLO identical to the reference formula.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _pick_block(n, preferred):
    b = min(n, preferred)
    while n % b != 0:
        b -= 1
    return b


def _ffn_kernel(x_ref, wi0_ref, wi1_ref, wo_ref, o_ref):
    """One (m-tile, f-tile) program; accumulate partial product over f tiles."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)  # [bm, K]
    gate = jax.nn.gelu(x @ wi0_ref[...].astype(jnp.float32), approximate=True)
    lin = x @ wi1_ref[...].astype(jnp.float32)
    h = gate * lin  # [bm, bf]
    o_ref[...] += (h @ wo_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def _ffn_pallas(x, wi_0, wi_1, wo, block_m, block_f):
    m, k = x.shape
    f = wi_0.shape[1]
    bm = _pick_block(m, block_m)
    bf = _pick_block(f, block_f)
    return pl.pallas_call(
        _ffn_kernel,
        grid=(m // bm, f // bf),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bf), lambda i, j: (0, j)),
            pl.BlockSpec((k, bf), lambda i, j: (0, j)),
            pl.BlockSpec((bf, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), x.dtype),
        interpret=True,
    )(x, wi_0, wi_1, wo)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def fused_ffn(x, wi_0, wi_1, wo, block_m=128, block_f=128):
    """Fused gated-GeLU MLP: ``(gelu(x@wi_0) * (x@wi_1)) @ wo``.

    Args:
      x: [M, d_model] activations.
      wi_0 / wi_1: [d_model, d_ff] gate / linear projections.
      wo: [d_ff, d_model] output projection.
      block_m / block_f: tile sizes over rows / hidden dim.
    """
    return _ffn_pallas(x, wi_0, wi_1, wo, block_m, block_f)


def _ffn_fwd(x, wi_0, wi_1, wo, block_m, block_f):
    y = _ffn_pallas(x, wi_0, wi_1, wo, block_m, block_f)
    return y, (x, wi_0, wi_1, wo)


def _ffn_bwd(block_m, block_f, res, dy):
    x, wi_0, wi_1, wo = res
    _, vjp = jax.vjp(ref.gated_ffn_ref, x, wi_0, wi_1, wo)
    return vjp(dy)


fused_ffn.defvjp(_ffn_fwd, _ffn_bwd)
