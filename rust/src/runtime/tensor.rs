//! Host-side tensors: the currency between seqio infeed, the PJRT runtime,
//! the partitioner/collectives, and the optimizers.
//!
//! Storage is `Arc`-backed: `HostTensor::clone` is O(1) regardless of
//! tensor size, so hot loops (the decode engine re-feeding the full
//! parameter set every step, `params_in_order(..).clone()` in eval paths)
//! share one allocation instead of deep-copying parameter bytes. Mutation
//! goes through copy-on-write: [`HostTensor::as_f32_mut`] /
//! [`HostTensor::as_i32_mut`] clone the underlying buffer only when it is
//! actually shared.

use std::sync::Arc;

use xla::Literal;

/// Typed flat storage, shared by cheap clones (copy-on-write on mutation).
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Arc<Vec<f32>>),
    I32(Arc<Vec<i32>>),
}

/// A dense row-major host tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Self { shape, data: TensorData::F32(Arc::new(data)) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Self { shape, data: TensorData::I32(Arc::new(data)) }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self::f32(shape, vec![0.0; n])
    }

    pub fn scalar_f32(v: f32) -> Self {
        Self::f32(vec![], vec![v])
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_size(&self) -> usize {
        self.elements() * 4
    }

    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            TensorData::F32(v) => v,
            TensorData::I32(_) => panic!("expected f32 tensor"),
        }
    }

    /// Mutable access with copy-on-write: if the buffer is shared with
    /// other clones, it is detached (cloned) first, so mutations never
    /// alias into another tensor.
    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            TensorData::F32(v) => Arc::make_mut(v),
            TensorData::I32(_) => panic!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            TensorData::I32(v) => v,
            TensorData::F32(_) => panic!("expected i32 tensor"),
        }
    }

    /// Copy-on-write mutable access for i32 tensors (see
    /// [`HostTensor::as_f32_mut`]).
    pub fn as_i32_mut(&mut self) -> &mut [i32] {
        match &mut self.data {
            TensorData::I32(v) => Arc::make_mut(v),
            TensorData::F32(_) => panic!("expected i32 tensor"),
        }
    }

    /// True if this tensor shares its buffer with at least one other clone
    /// (diagnostics/tests for the COW contract).
    pub fn is_shared(&self) -> bool {
        match &self.data {
            TensorData::F32(v) => Arc::strong_count(v) > 1,
            TensorData::I32(v) => Arc::strong_count(v) > 1,
        }
    }

    pub fn first_f32(&self) -> f32 {
        self.as_f32()[0]
    }

    /// L2 norm (f32 tensors).
    pub fn norm(&self) -> f64 {
        self.as_f32().iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Elementwise sum of two same-shape f32 tensors — the host-side
    /// residual add / gradient accumulation of the block execution path.
    pub fn add(&self, other: &HostTensor) -> HostTensor {
        assert_eq!(self.shape, other.shape, "add shape mismatch");
        let out = self
            .as_f32()
            .iter()
            .zip(other.as_f32())
            .map(|(a, b)| a + b)
            .collect();
        HostTensor::f32(self.shape.clone(), out)
    }

    // ---- slicing / concatenation (partitioning primitives) --------------

    /// Slice `count` elements starting at `start` along `axis`.
    pub fn slice_axis(&self, axis: usize, start: usize, count: usize) -> HostTensor {
        assert!(axis < self.shape.len(), "axis {axis} out of range");
        assert!(start + count <= self.shape[axis], "slice out of range");
        let outer: usize = self.shape[..axis].iter().product();
        let inner: usize = self.shape[axis + 1..].iter().product();
        let dim = self.shape[axis];
        let mut new_shape = self.shape.clone();
        new_shape[axis] = count;
        match &self.data {
            TensorData::F32(v) => {
                let mut out = Vec::with_capacity(outer * count * inner);
                for o in 0..outer {
                    let base = o * dim * inner + start * inner;
                    out.extend_from_slice(&v[base..base + count * inner]);
                }
                HostTensor::f32(new_shape, out)
            }
            TensorData::I32(v) => {
                let mut out = Vec::with_capacity(outer * count * inner);
                for o in 0..outer {
                    let base = o * dim * inner + start * inner;
                    out.extend_from_slice(&v[base..base + count * inner]);
                }
                HostTensor::i32(new_shape, out)
            }
        }
    }

    /// Slice a block: `ranges[i] = (start, len)` per dimension (the
    /// partitioner's host-block extraction; see
    /// `PartitionSpec::host_ranges`).
    pub fn slice_ranges(&self, ranges: &[(usize, usize)]) -> HostTensor {
        assert_eq!(ranges.len(), self.shape.len(), "rank mismatch");
        let mut out = self.clone();
        for (axis, &(start, len)) in ranges.iter().enumerate() {
            if (start, len) != (0, out.shape[axis]) {
                out = out.slice_axis(axis, start, len);
            }
        }
        out
    }

    /// Concatenate tensors along `axis` (all other dims must match).
    pub fn concat_axis(parts: &[HostTensor], axis: usize) -> HostTensor {
        assert!(!parts.is_empty());
        let first = &parts[0];
        let outer: usize = first.shape[..axis].iter().product();
        let inner: usize = first.shape[axis + 1..].iter().product();
        let total_dim: usize = parts.iter().map(|p| p.shape[axis]).sum();
        let mut new_shape = first.shape.clone();
        new_shape[axis] = total_dim;
        let is_f32 = matches!(first.data, TensorData::F32(_));
        let mut out_f = Vec::new();
        let mut out_i = Vec::new();
        if is_f32 {
            out_f.reserve(outer * total_dim * inner);
        } else {
            out_i.reserve(outer * total_dim * inner);
        }
        for o in 0..outer {
            for p in parts {
                let dim = p.shape[axis];
                match &p.data {
                    TensorData::F32(v) => {
                        out_f.extend_from_slice(&v[o * dim * inner..(o + 1) * dim * inner])
                    }
                    TensorData::I32(v) => {
                        out_i.extend_from_slice(&v[o * dim * inner..(o + 1) * dim * inner])
                    }
                }
            }
        }
        if is_f32 {
            HostTensor::f32(new_shape, out_f)
        } else {
            HostTensor::i32(new_shape, out_i)
        }
    }

    // ---- PJRT literal conversion -----------------------------------------

    pub fn to_literal(&self) -> anyhow::Result<Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => Literal::vec1(v.as_slice()),
            TensorData::I32(v) => Literal::vec1(v.as_slice()),
        };
        if self.shape.is_empty() {
            // scalar: reshape to rank-0
            Ok(lit.reshape(&[])?)
        } else {
            Ok(lit.reshape(&dims)?)
        }
    }

    pub fn from_literal(lit: &Literal) -> anyhow::Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::f32(dims, lit.to_vec::<f32>()?)),
            xla::ElementType::S32 => Ok(HostTensor::i32(dims, lit.to_vec::<i32>()?)),
            other => anyhow::bail!("unsupported literal element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_and_concat_roundtrip_axis0() {
        let t = HostTensor::f32(vec![4, 3], (0..12).map(|i| i as f32).collect());
        let a = t.slice_axis(0, 0, 2);
        let b = t.slice_axis(0, 2, 2);
        assert_eq!(a.shape, vec![2, 3]);
        assert_eq!(a.as_f32(), &[0., 1., 2., 3., 4., 5.]);
        let back = HostTensor::concat_axis(&[a, b], 0);
        assert_eq!(back, t);
    }

    #[test]
    fn slice_and_concat_roundtrip_axis1() {
        let t = HostTensor::f32(vec![2, 4], (0..8).map(|i| i as f32).collect());
        let a = t.slice_axis(1, 0, 2);
        let b = t.slice_axis(1, 2, 2);
        assert_eq!(a.as_f32(), &[0., 1., 4., 5.]);
        assert_eq!(b.as_f32(), &[2., 3., 6., 7.]);
        let back = HostTensor::concat_axis(&[a, b], 1);
        assert_eq!(back, t);
    }

    #[test]
    fn slice_ranges_extracts_block() {
        let t = HostTensor::f32(vec![4, 4], (0..16).map(|i| i as f32).collect());
        let b = t.slice_ranges(&[(2, 2), (0, 2)]);
        assert_eq!(b.shape, vec![2, 2]);
        assert_eq!(b.as_f32(), &[8., 9., 12., 13.]);
        // full ranges are an O(1) clone
        let full = t.slice_ranges(&[(0, 4), (0, 4)]);
        assert_eq!(full, t);
        assert!(t.is_shared());
    }

    #[test]
    fn i32_slicing() {
        let t = HostTensor::i32(vec![2, 2], vec![1, 2, 3, 4]);
        let a = t.slice_axis(1, 1, 1);
        assert_eq!(a.as_i32(), &[2, 4]);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn shape_mismatch_panics() {
        HostTensor::f32(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn add_elementwise() {
        let a = HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = HostTensor::f32(vec![2, 2], vec![0.5, -2.0, 1.0, 0.0]);
        assert_eq!(a.add(&b).as_f32(), &[1.5, 0.0, 4.0, 4.0]);
    }

    #[test]
    fn norm_computes() {
        let t = HostTensor::f32(vec![2], vec![3.0, 4.0]);
        assert!((t.norm() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn clone_shares_storage_until_mutation() {
        let a = HostTensor::f32(vec![3], vec![1.0, 2.0, 3.0]);
        let mut b = a.clone();
        assert!(a.is_shared() && b.is_shared(), "clone must share the buffer");
        // COW: mutating b detaches it, a is untouched
        b.as_f32_mut()[0] = 99.0;
        assert!(!a.is_shared() && !b.is_shared());
        assert_eq!(a.as_f32(), &[1.0, 2.0, 3.0]);
        assert_eq!(b.as_f32(), &[99.0, 2.0, 3.0]);
    }

    #[test]
    fn unshared_mutation_does_not_copy() {
        // Arc::make_mut on a unique tensor mutates in place: the data
        // pointer must be stable across mutations.
        let mut t = HostTensor::i32(vec![2], vec![7, 8]);
        let p0 = t.as_i32().as_ptr();
        t.as_i32_mut()[1] = 9;
        assert_eq!(t.as_i32().as_ptr(), p0);
        assert_eq!(t.as_i32(), &[7, 9]);
    }
}
