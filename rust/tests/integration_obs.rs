//! Integration: observability layer (PR 7) — a 2-step block-mode train
//! with `trace_out` set must emit parseable Chrome-trace JSON containing
//! one `coll/<point>` span per manifest [`CollectiveStep`]; a trainer fed
//! by a deliberately slow infeed must register
//! `train/infeed_starved_steps` and classify as infeed-bound in
//! `trace-summary`; a healthy single-host synthetic run must classify as
//! compute-bound.

use t5x::partitioning::{ExecMode, Mesh};
use t5x::runtime::{Artifacts, DeviceHandle};
use t5x::seqio::dataset::Dataset;
use t5x::seqio::{ints_example, Example, Feature};
use t5x::trainer::infeed::Infeed;
use t5x::trainer::{BatchSource, Trainer, TrainerConfig};
use t5x::util::json::Json;

fn trace_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("obs_{tag}_{}.json", std::process::id()))
}

/// Load a trace file and return its event array, checking the envelope
/// shape and that every complete event is well-formed (ph present,
/// `X` events carry a non-negative duration).
fn load_events(path: &std::path::Path) -> Vec<Json> {
    let v = Json::parse_file(path).expect("trace file must be parseable JSON");
    let events = v
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .expect("trace must be a {\"traceEvents\": [...]} envelope")
        .clone();
    let mut begins: i64 = 0;
    for ev in &events {
        let ph = ev.get("ph").and_then(|p| p.as_str()).expect("event without ph");
        match ph {
            "X" => {
                let dur = ev.get("dur").and_then(|d| d.as_f64()).expect("X without dur");
                assert!(dur >= 0.0, "negative span duration: {ev}");
                assert!(ev.get("name").and_then(|n| n.as_str()).is_some());
            }
            "B" => begins += 1,
            "E" => begins -= 1,
            // counters and metadata
            "C" | "M" => {}
            other => panic!("unexpected event phase {other:?}"),
        }
        assert!(begins >= 0, "E event without matching B");
    }
    assert_eq!(begins, 0, "unbalanced B/E events");
    events
}

fn span_names(events: &[Json]) -> Vec<String> {
    events
        .iter()
        .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
        .filter_map(|e| e.get("name").and_then(|n| n.as_str()).map(str::to_string))
        .collect()
}

#[test]
fn block_mode_trace_has_span_per_manifest_collective() {
    let arts = Artifacts::load_default().unwrap();
    let m = arts.model("t5-nano-dec").unwrap();
    if !m.supports_block_exec(2) {
        eprintln!("skipping: artifacts carry no block contract for model=2");
        return;
    }
    let device = DeviceHandle::spawn().unwrap();
    let path = trace_path("block");
    let steps = 2u64;
    let mut cfg = TrainerConfig::quick("t5-nano-dec", steps);
    cfg.mesh = Mesh::new(1, 2);
    cfg.exec_mode = ExecMode::Block;
    cfg.trace_out = Some(path.clone());
    let trainer = Trainer::new(&arts, &device, cfg).unwrap();
    let summary = trainer.train(&BatchSource::Synthetic { seed: 3 }).unwrap();
    assert_eq!(summary.history.len(), steps as usize);

    let events = load_events(&path);
    let names = span_names(&events);

    // one coll/<point> span per manifest CollectiveStep, for every rank
    // and every step (the block executor replays the ordered schedule)
    let sched = &m.block_exec(2).unwrap().collectives;
    assert!(!sched.is_empty());
    for c in sched {
        let want = format!("coll/{}", c.point);
        let got = names.iter().filter(|n| **n == want).count();
        assert!(
            got >= steps as usize,
            "manifest collective {want}: {got} spans < {steps} steps"
        );
    }
    let coll_total = names.iter().filter(|n| n.starts_with("coll/")).count();
    // 2 ranks x 2 steps x full schedule
    assert!(
        coll_total >= 2 * steps as usize * sched.len(),
        "coll spans {coll_total} < ranks*steps*schedule {}",
        2 * steps as usize * sched.len()
    );

    // per-segment compute spans and the step umbrella span
    assert!(names.iter().any(|n| n.starts_with("seg/")), "no seg/ spans");
    assert_eq!(
        names.iter().filter(|n| *n == "train/step").count(),
        2 * steps as usize,
        "expected one train/step span per rank per step"
    );

    // the analyzer must load it and must not blame the (absent) infeed
    let ts = t5x::obs::summarize_file(&path).unwrap();
    assert_ne!(ts.verdict, "infeed-bound", "synthetic source cannot be infeed-bound");
    assert!(ts.spans.iter().any(|s| s.name == "train/step"));

    let _ = std::fs::remove_file(&path);
    device.shutdown();
}

fn slow_converted_example(m: &t5x::runtime::artifacts::ModelManifest, val: i32) -> Example {
    let l = m.seq_len();
    let mut ex = ints_example(&[
        ("decoder_input_tokens", vec![val.rem_euclid(13) + 2; l]),
        ("decoder_target_tokens", vec![val.rem_euclid(13) + 2; l]),
    ]);
    ex.insert("decoder_loss_weights".into(), Feature::Floats(vec![1.0; l]));
    ex
}

#[test]
fn slow_source_trace_is_infeed_bound() {
    let arts = Artifacts::load_default().unwrap();
    let m = arts.model("t5-nano-dec").unwrap();
    let device = DeviceHandle::spawn().unwrap();
    let path = trace_path("starved");
    let steps = 3u64;
    let mut cfg = TrainerConfig::quick("t5-nano-dec", steps);
    cfg.trace_out = Some(path.clone());
    let trainer = Trainer::new(&arts, &device, cfg).unwrap();

    // Every example costs 5ms, so each batch takes batch*5ms to produce
    // while a nano train step is far cheaper: the consumer drains the
    // prefetch pipe and blocks — the infeed-bound signature.
    let b = m.batch();
    let m2 = m.clone();
    let infeed = Infeed::spawn(m, 1, 1, move |_| {
        let m3 = m2.clone();
        Dataset::new((0..(b as u64 * steps) as i32).map(move |i| {
            std::thread::sleep(std::time::Duration::from_millis(5));
            slow_converted_example(&m3, i)
        }))
    });
    let summary = trainer.train(&BatchSource::Infeed(infeed)).unwrap();
    assert_eq!(summary.history.len(), steps as usize);
    assert!(
        trainer.counters.get("train/infeed_starved_steps") >= 1,
        "slow producer must starve the trainer, counter = {}",
        trainer.counters.get("train/infeed_starved_steps")
    );

    let events = load_events(&path);
    let names = span_names(&events);
    assert!(names.iter().any(|n| n == "infeed/batch"), "producer spans missing");
    assert!(names.iter().any(|n| n == "train/infeed"), "consumer wait spans missing");

    let ts = t5x::obs::summarize_file(&path).unwrap();
    assert_eq!(ts.verdict, "infeed-bound", "summary: {ts:?}");
    assert!(ts.counters.get("train/infeed_starved_steps").copied().unwrap_or(0.0) >= 1.0);

    let _ = std::fs::remove_file(&path);
    device.shutdown();
}

#[test]
fn healthy_synthetic_trace_is_compute_bound() {
    let arts = Artifacts::load_default().unwrap();
    let device = DeviceHandle::spawn().unwrap();
    let path = trace_path("healthy");
    let mut cfg = TrainerConfig::quick("t5-nano-dec", 4);
    cfg.trace_out = Some(path.clone());
    let trainer = Trainer::new(&arts, &device, cfg).unwrap();
    trainer.train(&BatchSource::Synthetic { seed: 9 }).unwrap();

    let ts = t5x::obs::summarize_file(&path).unwrap();
    assert_eq!(ts.verdict, "compute-bound", "summary: {ts:?}");
    // phase percentiles also land in the logger-facing histograms
    assert!(trainer.phase_hist.step_ms.count() >= 4);
    assert!(trainer.phase_hist.step_ms.p99() >= trainer.phase_hist.step_ms.p50());

    let _ = std::fs::remove_file(&path);
    device.shutdown();
}

#[test]
fn profile_window_limits_trace_to_requested_steps() {
    let arts = Artifacts::load_default().unwrap();
    let device = DeviceHandle::spawn().unwrap();
    let path = trace_path("window");
    let mut cfg = TrainerConfig::quick("t5-nano-dec", 6);
    cfg.trace_out = Some(path.clone());
    cfg.profile_steps = Some((3, 5)); // trace steps 3 and 4 only
    let trainer = Trainer::new(&arts, &device, cfg).unwrap();
    trainer.train(&BatchSource::Synthetic { seed: 5 }).unwrap();

    let events = load_events(&path);
    let steps: Vec<f64> = events
        .iter()
        .filter(|e| e.get("name").and_then(|n| n.as_str()) == Some("train/step"))
        .filter_map(|e| e.get("args").and_then(|a| a.get("step")).and_then(|s| s.as_f64()))
        .collect();
    assert_eq!(steps.len(), 2, "profile window 3..5 must trace exactly 2 steps: {steps:?}");
    assert!(steps.iter().all(|&s| (3.0..5.0).contains(&s)), "steps outside window: {steps:?}");

    let _ = std::fs::remove_file(&path);
    device.shutdown();
}
