//! Deterministic, splittable PRNG (rand-crate substitute).
//!
//! Two generators:
//! * [`SplitMix64`] — the exact splitmix64 used by `model.pattern_init` on
//!   the Python side; parameter "pattern" initialization must be bit-equal
//!   across languages for the golden tests.
//! * [`Pcg64`] — the workhorse stream RNG used by seqio shuffling, synthetic
//!   data generation and parameter init. Seeded, splittable by `fold_in`.

/// splitmix64 step (Vigna). Must match `python/compile/model.py`.
#[inline]
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// FNV-1a 64-bit hash. Must match `python/compile/model.py`.
#[inline]
pub fn fnv1a64(s: &str) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for b in s.as_bytes() {
        h = (h ^ (*b as u64)).wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Stateless splitmix64 stream used for cross-language pattern init.
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32 with 128-bit-ish state emulated as two u64 lanes.
/// Deterministic across platforms; not cryptographic.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xDA3E_39CB_94B9_5BDB)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Self { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(splitmix64(seed));
        rng.next_u32();
        rng
    }

    /// Expose the raw generator state for checkpointing (seqio pipeline
    /// state). Round-trips exactly through [`Pcg64::from_raw_state`].
    pub fn raw_state(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from [`Pcg64::raw_state`] output. The restored
    /// generator continues the exact stream of the saved one.
    pub fn from_raw_state(state: u64, inc: u64) -> Pcg64 {
        Pcg64 { state, inc }
    }

    /// Derive an independent generator (jax.random.fold_in analog).
    pub fn fold_in(&self, data: u64) -> Pcg64 {
        Pcg64::with_stream(
            splitmix64(self.state ^ splitmix64(data)),
            splitmix64(self.inc ^ data.rotate_left(17)),
        )
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, n) without modulo bias (Lemire).
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = widening_mul(x, n);
            if lo >= n || lo >= x.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal via Box-Muller.
    pub fn next_normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Truncated (±2σ, re-draw) normal, the t5x parameter-init default.
    pub fn next_trunc_normal(&mut self) -> f64 {
        loop {
            let x = self.next_normal();
            if x.abs() <= 2.0 {
                return x;
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized weights.
    pub fn sample_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[inline]
fn widening_mul(a: u64, b: u64) -> (u64, u64) {
    let r = (a as u128) * (b as u128);
    ((r >> 64) as u64, r as u64)
}

/// Cross-language deterministic parameter init, mirroring
/// `model.pattern_init`: value[i] = (2*u[i] - 1) * scale with
/// u[i] = splitmix64(fnv1a64(name) ^ seed ^ (i+1)) >> 11 scaled to [0,1).
pub fn pattern_init(name: &str, count: usize, scale: f32, seed: u64) -> Vec<f32> {
    let base = fnv1a64(name) ^ seed;
    (0..count)
        .map(|i| {
            let u = splitmix64(base ^ (i as u64 + 1)) >> 11;
            let f = u as f64 * (1.0 / (1u64 << 53) as f64);
            ((2.0 * f - 1.0) * scale as f64) as f32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference values from the canonical splitmix64 with seed 1234567.
        let mut sm = SplitMix64::new(1234567);
        let v: Vec<u64> = (0..3).map(|_| sm.next_u64()).collect();
        assert_eq!(v[0], splitmix64(1234567));
    }

    #[test]
    fn fnv_matches_python_formula() {
        // Value computed from the same algorithm in python (see model.py).
        assert_eq!(fnv1a64(""), 0xCBF2_9CE4_8422_2325);
        assert_ne!(fnv1a64("a"), fnv1a64("b"));
    }

    #[test]
    fn pcg_deterministic_and_uniformish() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
        // mean of uniforms ~ 0.5
        let mut r = Pcg64::new(7);
        let mean: f64 = (0..10_000).map(|_| r.next_f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn next_below_unbiased_range() {
        let mut r = Pcg64::new(1);
        for _ in 0..1000 {
            assert!(r.next_below(7) < 7);
        }
    }

    #[test]
    fn fold_in_independent() {
        let r = Pcg64::new(9);
        let mut a = r.fold_in(1);
        let mut b = r.fold_in(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn trunc_normal_bounded() {
        let mut r = Pcg64::new(3);
        for _ in 0..1000 {
            assert!(r.next_trunc_normal().abs() <= 2.0);
        }
    }

    #[test]
    fn raw_state_roundtrip_continues_stream() {
        let mut a = Pcg64::new(17);
        for _ in 0..13 {
            a.next_u64();
        }
        let (s, i) = a.raw_state();
        let mut b = Pcg64::from_raw_state(s, i);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn pattern_init_salted_and_bounded() {
        let a = pattern_init("x", 100, 0.05, 0);
        let b = pattern_init("x", 100, 0.05, 0);
        let c = pattern_init("y", 100, 0.05, 0);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(a.iter().all(|v| v.abs() <= 0.05));
    }
}
