//! Checkpointing (paper §2.1 "Checkpointing", S4): multi-host sliced
//! parameter + optimizer-state checkpoints over the [`tstore`] chunked
//! array store, with atomic commit, retention, async save, and a legacy
//! single-file format + converter (the paper's Mesh-TF compatibility
//! claim: converted native checkpoints read faster — measured by
//! `bench_checkpoint`).

pub mod legacy;
pub mod tstore;

use std::path::{Path, PathBuf};

use crate::model::Params;
use crate::runtime::HostTensor;
use crate::seqio::dataset::PipelineState;
use crate::util::json::Json;

/// Extra (non-parameter) f32 vectors saved alongside params — optimizer
/// slots, keyed "optstate/<param>/<slot>".
pub type ExtraState = Vec<(String, Vec<f32>)>;

pub struct CheckpointManager {
    pub dir: PathBuf,
    /// Keep the most recent N checkpoints (t5x `keep`).
    pub retain: usize,
    /// Rows per tstore chunk.
    pub chunk_rows: usize,
}

impl CheckpointManager {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        Self { dir: dir.into(), retain: 3, chunk_rows: 1024 }
    }

    fn step_dir(&self, step: u64) -> PathBuf {
        self.dir.join(format!("ckpt-{step:08}"))
    }

    /// All available checkpoint steps, ascending.
    pub fn steps(&self) -> Vec<u64> {
        let mut out = Vec::new();
        if let Ok(rd) = std::fs::read_dir(&self.dir) {
            for e in rd.flatten() {
                if let Some(name) = e.file_name().to_str() {
                    if let Some(num) = name.strip_prefix("ckpt-") {
                        if let Ok(step) = num.parse::<u64>() {
                            out.push(step);
                        }
                    }
                }
            }
        }
        out.sort();
        out
    }

    pub fn latest(&self) -> Option<u64> {
        self.steps().last().copied()
    }

    /// Save synchronously: params + extra state + metadata, atomic rename.
    pub fn save(&self, step: u64, params: &Params, extra: &ExtraState) -> anyhow::Result<()> {
        self.save_with_pipeline(step, params, extra, None)
    }

    /// [`CheckpointManager::save`] plus the per-host data-pipeline states,
    /// persisted as a CRC-protected tstore byte array (`pipeline/state`,
    /// a JSON array with one entry per host) inside the same atomic
    /// checkpoint directory.
    pub fn save_with_pipeline(
        &self,
        step: u64,
        params: &Params,
        extra: &ExtraState,
        pipeline: Option<&[PipelineState]>,
    ) -> anyhow::Result<()> {
        let final_dir = self.step_dir(step);
        let tmp = final_dir.with_extension("tmp");
        if tmp.exists() {
            std::fs::remove_dir_all(&tmp)?;
        }
        std::fs::create_dir_all(&tmp)?;
        // parallel parameter writes (multi-host writers in t5x; threads here)
        let names: Vec<&String> = params.keys().collect();
        crate::util::threads::parallel_map(names.len(), 8, |i| {
            let t = &params[names[i]];
            tstore::write_full(&tmp, &format!("params/{}", names[i]), t, self.chunk_rows)
                .expect("param write");
        });
        for (key, vec) in extra {
            let t = HostTensor::f32(vec![vec.len()], vec.clone());
            tstore::write_full(&tmp, &format!("optstate/{key}"), &t, self.chunk_rows)?;
        }
        if let Some(states) = pipeline {
            let arr = Json::Arr(states.iter().map(|s| s.0.clone()).collect());
            tstore::write_bytes(
                &tmp,
                "pipeline/state",
                arr.to_string().as_bytes(),
                64 * 1024,
            )?;
        }
        let meta = Json::obj(vec![
            ("step", Json::num(step as f64)),
            ("num_params", Json::num(params.len() as f64)),
            ("has_pipeline", Json::Bool(pipeline.is_some())),
            ("format", Json::str("t5x-native-v1")),
        ]);
        std::fs::write(tmp.join("checkpoint.json"), meta.to_string())?;
        if final_dir.exists() {
            std::fs::remove_dir_all(&final_dir)?;
        }
        std::fs::rename(&tmp, &final_dir)?;
        self.apply_retention()?;
        Ok(())
    }

    /// Async save on a snapshot (t5x saves without blocking the train
    /// loop). `pipeline` carries the per-host data-pipeline states
    /// captured with the snapshot, so async checkpoints are just as
    /// resumable as synchronous ones (pass `None` for synthetic sources).
    pub fn save_async(
        &self,
        step: u64,
        params: Params,
        extra: ExtraState,
        pipeline: Option<Vec<PipelineState>>,
    ) -> std::thread::JoinHandle<anyhow::Result<()>> {
        let mgr = CheckpointManager {
            dir: self.dir.clone(),
            retain: self.retain,
            chunk_rows: self.chunk_rows,
        };
        std::thread::spawn(move || {
            mgr.save_with_pipeline(step, &params, &extra, pipeline.as_deref())
        })
    }

    fn apply_retention(&self) -> anyhow::Result<()> {
        let steps = self.steps();
        if steps.len() > self.retain {
            for &old in &steps[..steps.len() - self.retain] {
                std::fs::remove_dir_all(self.step_dir(old))?;
            }
        }
        Ok(())
    }

    /// Restore all params (full tensors) + extra state at `step`.
    pub fn restore(&self, step: u64) -> anyhow::Result<(Params, ExtraState)> {
        let dir = self.step_dir(step);
        anyhow::ensure!(dir.exists(), "no checkpoint at step {step} in {}", self.dir.display());
        let mut params = Params::new();
        let proot = dir.join("params");
        for name in collect_array_names(&proot)? {
            let t = tstore::read_full(&proot, &name)
                .map_err(|e| anyhow::anyhow!("restoring {name}: {e}"))?;
            params.insert(name, t);
        }
        let mut extra = ExtraState::new();
        let oroot = dir.join("optstate");
        if oroot.exists() {
            for name in collect_array_names(&oroot)? {
                let t = tstore::read_full(&oroot, &name)?;
                extra.push((name, t.as_f32().to_vec()));
            }
        }
        Ok((params, extra))
    }

    /// Restore the per-host data-pipeline states saved at `step`, or None
    /// for checkpoints written without pipeline state.
    pub fn restore_pipeline(&self, step: u64) -> anyhow::Result<Option<Vec<PipelineState>>> {
        let dir = self.step_dir(step);
        let bytes = match tstore::read_bytes(&dir, "pipeline/state") {
            Ok(b) => b,
            Err(tstore::TStoreError::NotFound(_)) => return Ok(None),
            Err(e) => return Err(e.into()),
        };
        let text = String::from_utf8(bytes)
            .map_err(|e| anyhow::anyhow!("pipeline state is not utf-8: {e}"))?;
        let arr = match Json::parse(&text)? {
            Json::Arr(a) => a,
            other => anyhow::bail!("pipeline state is not a JSON array: {other}"),
        };
        Ok(Some(arr.into_iter().map(PipelineState).collect()))
    }

    /// Restore a row-slice of one parameter (read-with-resharding: a host
    /// pulls only its shard regardless of the saving topology).
    pub fn restore_param_slice(
        &self,
        step: u64,
        name: &str,
        start_row: usize,
        rows: usize,
    ) -> anyhow::Result<Vec<f32>> {
        let proot = self.step_dir(step).join("params");
        let meta = tstore::open_array(&proot, name)?;
        Ok(tstore::read_slice(&proot, name, &meta, start_row, rows)?)
    }
}

/// Array names under a tstore root, including nested (slash-joined) names.
fn collect_array_names(root: &Path) -> anyhow::Result<Vec<String>> {
    fn walk(dir: &Path, prefix: String, out: &mut Vec<String>) -> anyhow::Result<()> {
        if dir.join("meta.json").exists() {
            out.push(prefix);
            return Ok(());
        }
        for e in std::fs::read_dir(dir)? {
            let p = e?.path();
            if p.is_dir() {
                let name = p.file_name().unwrap().to_string_lossy().into_owned();
                let next = if prefix.is_empty() { name } else { format!("{prefix}/{name}") };
                walk(&p, next, out)?;
            }
        }
        Ok(())
    }
    let mut out = Vec::new();
    if root.exists() {
        walk(root, String::new(), &mut out)?;
    }
    out.sort();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ckptmgr_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn fake_params() -> Params {
        let mut p = Params::new();
        p.insert(
            "decoder.layers_0.wq".into(),
            HostTensor::f32(vec![8, 4], (0..32).map(|i| i as f32).collect()),
        );
        p.insert("final_norm.scale".into(), HostTensor::f32(vec![4], vec![1.0; 4]));
        p
    }

    #[test]
    fn save_restore_roundtrip_with_optstate() {
        let dir = tmp("rt");
        let mgr = CheckpointManager::new(&dir);
        let params = fake_params();
        let extra: ExtraState =
            vec![("decoder.layers_0.wq/m".into(), vec![0.5; 32])];
        mgr.save(100, &params, &extra).unwrap();
        assert_eq!(mgr.latest(), Some(100));
        let (back, ex) = mgr.restore(100).unwrap();
        assert_eq!(back, params);
        assert_eq!(ex.len(), 1);
        assert_eq!(ex[0].0, "decoder.layers_0.wq/m");
        assert_eq!(ex[0].1, vec![0.5; 32]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pipeline_state_saved_and_restored() {
        let dir = tmp("pipe");
        let mgr = CheckpointManager::new(&dir);
        let mk = |k: f64| {
            PipelineState(Json::obj(vec![
                ("op", Json::str("det_reader")),
                ("emitted_total", Json::num(k)),
            ]))
        };
        let states = vec![mk(42.0), mk(17.0)];
        mgr.save_with_pipeline(5, &fake_params(), &Vec::new(), Some(&states))
            .unwrap();
        let back = mgr.restore_pipeline(5).unwrap().unwrap();
        assert_eq!(back, states);
        // plain saves carry no pipeline state
        mgr.save(6, &fake_params(), &Vec::new()).unwrap();
        assert!(mgr.restore_pipeline(6).unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn retention_keeps_last_n() {
        let dir = tmp("retain");
        let mut mgr = CheckpointManager::new(&dir);
        mgr.retain = 2;
        let params = fake_params();
        for step in [1u64, 2, 3, 4] {
            mgr.save(step, &params, &Vec::new()).unwrap();
        }
        assert_eq!(mgr.steps(), vec![3, 4]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sliced_restore_for_resharding() {
        let dir = tmp("reshard");
        let mut mgr = CheckpointManager::new(&dir);
        mgr.chunk_rows = 2;
        let params = fake_params();
        mgr.save(7, &params, &Vec::new()).unwrap();
        // host 1 of 2 pulls rows 4..8 of the 8-row param
        let rows = mgr
            .restore_param_slice(7, "decoder.layers_0.wq", 4, 4)
            .unwrap();
        assert_eq!(rows, (16..32).map(|i| i as f32).collect::<Vec<_>>());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn async_save_completes() {
        let dir = tmp("async");
        let mgr = CheckpointManager::new(&dir);
        let h = mgr.save_async(3, fake_params(), Vec::new(), None);
        h.join().unwrap().unwrap();
        assert_eq!(mgr.latest(), Some(3));
        assert!(mgr.restore_pipeline(3).unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn async_save_carries_pipeline_state() {
        let dir = tmp("async_pipe");
        let mgr = CheckpointManager::new(&dir);
        let states = vec![PipelineState(Json::obj(vec![
            ("op", Json::str("vec")),
            ("pos", Json::num(9.0)),
        ]))];
        let h = mgr.save_async(4, fake_params(), Vec::new(), Some(states.clone()));
        h.join().unwrap().unwrap();
        assert_eq!(mgr.restore_pipeline(4).unwrap().unwrap(), states);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn restore_missing_step_errors() {
        let dir = tmp("missing");
        let mgr = CheckpointManager::new(&dir);
        assert!(mgr.restore(99).is_err());
    }
}
