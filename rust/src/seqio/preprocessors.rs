//! Preprocessors (seqio preprocessing steps, Figure 2): composable
//! dataset->dataset transforms. Stochastic preprocessors draw per-example
//! seeds derived from the pipeline seed + example index, so the same
//! pipeline seed always yields the same stream (§3.2 Reproducibility).

use std::sync::Arc;

use super::dataset::Dataset;
use super::vocab::{Vocabulary, EOS_ID};
use super::Feature;
use crate::util::rng::Pcg64;

/// Context threaded through preprocessing (the seqio `seed`).
#[derive(Clone, Debug)]
pub struct PipelineCtx {
    pub seed: u64,
}

/// A dataset-level transform.
pub trait Preprocessor: Send + Sync {
    fn name(&self) -> &'static str;
    fn apply(&self, ds: Dataset, ctx: &PipelineCtx) -> Dataset;
}

// ---------------------------------------------------------------------------

/// Tokenize: text feature -> int feature using a [`Vocabulary`].
pub struct Tokenize {
    pub vocab: Arc<dyn Vocabulary>,
    /// (input_key, output_key) pairs, e.g. [("text", "targets")].
    pub keys: Vec<(String, String)>,
}

impl Tokenize {
    pub fn new(vocab: Arc<dyn Vocabulary>, keys: &[(&str, &str)]) -> Self {
        Self {
            vocab,
            keys: keys.iter().map(|(a, b)| (a.to_string(), b.to_string())).collect(),
        }
    }
}

impl Preprocessor for Tokenize {
    fn name(&self) -> &'static str {
        "tokenize"
    }

    fn apply(&self, ds: Dataset, _ctx: &PipelineCtx) -> Dataset {
        let vocab = self.vocab.clone();
        let keys = self.keys.clone();
        ds.map(move |mut ex| {
            for (src, dst) in &keys {
                if let Some(Feature::Text(t)) = ex.get(src) {
                    let ids = vocab.encode(t);
                    ex.insert(dst.clone(), Feature::Ints(ids));
                }
            }
            ex
        })
    }
}

// ---------------------------------------------------------------------------

/// Split token streams into fixed-size chunks (one example per chunk) —
/// `split_tokens` in seqio; used to turn documents into training windows.
pub struct ChunkTokens {
    pub key: String,
    pub chunk_len: usize,
    /// Drop trailing chunks shorter than this fraction of chunk_len.
    pub min_fill: f32,
}

impl ChunkTokens {
    pub fn new(key: &str, chunk_len: usize) -> Self {
        Self { key: key.to_string(), chunk_len, min_fill: 0.25 }
    }
}

impl Preprocessor for ChunkTokens {
    fn name(&self) -> &'static str {
        "chunk_tokens"
    }

    fn apply(&self, ds: Dataset, _ctx: &PipelineCtx) -> Dataset {
        let key = self.key.clone();
        let len = self.chunk_len;
        let min = ((self.chunk_len as f32) * self.min_fill).ceil() as usize;
        ds.flat_map(move |ex| {
            let Some(Feature::Ints(ids)) = ex.get(&key) else {
                return vec![ex];
            };
            let mut out = Vec::new();
            for chunk in ids.chunks(len) {
                if chunk.len() < min && !out.is_empty() {
                    break;
                }
                let mut e2 = ex.clone();
                e2.insert(key.clone(), Feature::Ints(chunk.to_vec()));
                out.push(e2);
            }
            out
        })
    }
}

// ---------------------------------------------------------------------------

/// T5 span corruption (the pretraining objective of Raffel et al. 2020):
/// replaces random spans in `targets` with sentinels, producing
/// `inputs` = context with sentinel markers, `targets` = sentinel-delimited
/// span contents.
pub struct SpanCorruption {
    pub vocab: Arc<dyn Vocabulary>,
    pub noise_density: f32,
    pub mean_span_length: f32,
    /// Key holding the raw token stream (consumed), default "targets".
    pub key: String,
}

impl SpanCorruption {
    pub fn new(vocab: Arc<dyn Vocabulary>) -> Self {
        Self {
            vocab,
            noise_density: 0.15,
            mean_span_length: 3.0,
            key: "targets".to_string(),
        }
    }

    /// Core span-corruption math on one token sequence.
    pub fn corrupt(
        &self,
        tokens: &[i32],
        rng: &mut Pcg64,
    ) -> (Vec<i32>, Vec<i32>) {
        let n = tokens.len();
        if n < 2 {
            return (tokens.to_vec(), tokens.to_vec());
        }
        let num_noise = ((n as f32 * self.noise_density).round() as usize).clamp(1, n - 1);
        let num_spans = ((num_noise as f32 / self.mean_span_length).round() as usize)
            .clamp(1, num_noise)
            .min(self.vocab.extra_ids().saturating_sub(1).max(1));
        // Split num_noise into num_spans positive parts.
        let noise_lens = random_partition(num_noise, num_spans, rng);
        // Split the remaining tokens into num_spans+1 parts; interior parts
        // must be positive so spans don't merge.
        let num_keep = n - num_noise;
        let keep_lens = random_partition_allow_ends_zero(num_keep, num_spans + 1, rng);
        let mut inputs = Vec::with_capacity(n + num_spans);
        let mut targets = Vec::with_capacity(num_noise + num_spans + 1);
        let mut pos = 0usize;
        for k in 0..num_spans {
            let keep = keep_lens[k];
            inputs.extend_from_slice(&tokens[pos..pos + keep]);
            pos += keep;
            let sent = self.vocab.sentinel(k);
            inputs.push(sent);
            targets.push(sent);
            let noise = noise_lens[k];
            targets.extend_from_slice(&tokens[pos..pos + noise]);
            pos += noise;
        }
        inputs.extend_from_slice(&tokens[pos..]);
        targets.push(self.vocab.sentinel(num_spans));
        (inputs, targets)
    }
}

/// Split `total` into `parts` positive integers, uniformly at random
/// (stars and bars via sorted distinct cut points).
fn random_partition(total: usize, parts: usize, rng: &mut Pcg64) -> Vec<usize> {
    assert!(parts >= 1 && total >= parts, "total={total} parts={parts}");
    if parts == 1 {
        return vec![total];
    }
    // choose parts-1 distinct cut points in 1..total
    let mut cuts = Vec::with_capacity(parts - 1);
    while cuts.len() < parts - 1 {
        let c = 1 + rng.next_below((total - 1) as u64) as usize;
        if !cuts.contains(&c) {
            cuts.push(c);
        }
    }
    cuts.sort();
    let mut out = Vec::with_capacity(parts);
    let mut prev = 0;
    for c in cuts {
        out.push(c - prev);
        prev = c;
    }
    out.push(total - prev);
    out
}

/// Split `total` into `parts` parts where the first and last may be zero
/// but interior parts are positive when feasible.
fn random_partition_allow_ends_zero(
    total: usize,
    parts: usize,
    rng: &mut Pcg64,
) -> Vec<usize> {
    if parts == 1 {
        return vec![total];
    }
    let interior = parts - 2;
    if total >= interior && interior > 0 {
        // reserve 1 for each interior, distribute the rest over all parts
        let mut out = vec![0; parts];
        for slot in out.iter_mut().skip(1).take(interior) {
            *slot = 1;
        }
        let mut rest = total - interior;
        while rest > 0 {
            let i = rng.next_below(parts as u64) as usize;
            out[i] += 1;
            rest -= 1;
        }
        out
    } else {
        // degenerate: distribute uniformly
        let mut out = vec![0; parts];
        let mut rest = total;
        while rest > 0 {
            let i = rng.next_below(parts as u64) as usize;
            out[i] += 1;
            rest -= 1;
        }
        out
    }
}

impl Preprocessor for SpanCorruption {
    fn name(&self) -> &'static str {
        "span_corruption"
    }

    fn apply(&self, ds: Dataset, ctx: &PipelineCtx) -> Dataset {
        let me = SpanCorruption {
            vocab: self.vocab.clone(),
            noise_density: self.noise_density,
            mean_span_length: self.mean_span_length,
            key: self.key.clone(),
        };
        let seed = ctx.seed;
        ds.enumerate_map(move |i, mut ex| {
            let Some(Feature::Ints(ids)) = ex.get(&me.key).cloned() else {
                return ex;
            };
            let mut rng = Pcg64::new(seed).fold_in(i as u64);
            let (inputs, targets) = me.corrupt(&ids, &mut rng);
            ex.insert("inputs".into(), Feature::Ints(inputs));
            ex.insert("targets".into(), Feature::Ints(targets));
            ex
        })
    }
}

// ---------------------------------------------------------------------------

/// Prefix-LM objective: split the stream at a random pivot into
/// (inputs, targets) — the LaMDA-style decoder-only pretraining variant.
pub struct PrefixLm {
    pub key: String,
}

impl Default for PrefixLm {
    fn default() -> Self {
        Self { key: "targets".into() }
    }
}

impl Preprocessor for PrefixLm {
    fn name(&self) -> &'static str {
        "prefix_lm"
    }

    fn apply(&self, ds: Dataset, ctx: &PipelineCtx) -> Dataset {
        let key = self.key.clone();
        let seed = ctx.seed;
        ds.enumerate_map(move |i, mut ex| {
            let Some(Feature::Ints(ids)) = ex.get(&key).cloned() else {
                return ex;
            };
            if ids.len() < 2 {
                return ex;
            }
            let mut rng = Pcg64::new(seed ^ 0x9E37).fold_in(i as u64);
            let pivot = 1 + rng.next_below((ids.len() - 1) as u64) as usize;
            let (a, b) = ids.split_at(pivot);
            ex.insert("inputs".into(), Feature::Ints(a.to_vec()));
            ex.insert("targets".into(), Feature::Ints(b.to_vec()));
            ex
        })
    }
}

// ---------------------------------------------------------------------------

/// Append EOS to listed int features (seqio.append_eos).
pub struct AppendEos {
    pub keys: Vec<String>,
}

impl AppendEos {
    pub fn new(keys: &[&str]) -> Self {
        Self { keys: keys.iter().map(|s| s.to_string()).collect() }
    }
}

impl Preprocessor for AppendEos {
    fn name(&self) -> &'static str {
        "append_eos"
    }

    fn apply(&self, ds: Dataset, _ctx: &PipelineCtx) -> Dataset {
        let keys = self.keys.clone();
        ds.map(move |mut ex| {
            for k in &keys {
                if let Some(Feature::Ints(v)) = ex.get_mut(k) {
                    v.push(EOS_ID);
                }
            }
            ex
        })
    }
}

/// Trim int features to a maximum length (pre-converter safety).
pub struct TrimToLength {
    pub key: String,
    pub max_len: usize,
}

impl Preprocessor for TrimToLength {
    fn name(&self) -> &'static str {
        "trim"
    }

    fn apply(&self, ds: Dataset, _ctx: &PipelineCtx) -> Dataset {
        let key = self.key.clone();
        let max = self.max_len;
        ds.map(move |mut ex| {
            if let Some(Feature::Ints(v)) = ex.get_mut(&key) {
                v.truncate(max);
            }
            ex
        })
    }
}

/// Drop examples whose int feature is empty/too short.
pub struct FilterShort {
    pub key: String,
    pub min_len: usize,
}

impl Preprocessor for FilterShort {
    fn name(&self) -> &'static str {
        "filter_short"
    }

    fn apply(&self, ds: Dataset, _ctx: &PipelineCtx) -> Dataset {
        let key = self.key.clone();
        let min = self.min_len;
        ds.filter(move |ex| {
            ex.get(&key)
                .and_then(|f| f.as_ints())
                .map(|v| v.len() >= min)
                .unwrap_or(false)
        })
    }
}

/// Rename features (seqio.rekey).
pub struct Rekey {
    pub renames: Vec<(String, String)>,
}

impl Rekey {
    pub fn new(renames: &[(&str, &str)]) -> Self {
        Self {
            renames: renames
                .iter()
                .map(|(a, b)| (a.to_string(), b.to_string()))
                .collect(),
        }
    }
}

impl Preprocessor for Rekey {
    fn name(&self) -> &'static str {
        "rekey"
    }

    fn apply(&self, ds: Dataset, _ctx: &PipelineCtx) -> Dataset {
        let renames = self.renames.clone();
        ds.map(move |mut ex| {
            for (from, to) in &renames {
                if let Some(v) = ex.remove(from) {
                    ex.insert(to.clone(), v);
                }
            }
            ex
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::seqio::vocab::ByteVocabulary;
    use crate::seqio::{ints_example, text_example};

    fn ctx() -> PipelineCtx {
        PipelineCtx { seed: 42 }
    }

    #[test]
    fn tokenize_maps_text() {
        let v: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(4));
        let p = Tokenize::new(v.clone(), &[("text", "targets")]);
        let ds = Dataset::from_vec(vec![text_example(&[("text", "ab")])]);
        let out = p.apply(ds, &ctx()).collect_vec();
        assert_eq!(out[0]["targets"].as_ints().unwrap(), &[b'a' as i32 + 3, b'b' as i32 + 3]);
    }

    #[test]
    fn chunk_splits_and_drops_tiny_tails() {
        let p = ChunkTokens::new("targets", 4);
        let ds = Dataset::from_vec(vec![ints_example(&[("targets", (0..9).collect())])]);
        let out = p.apply(ds, &ctx()).collect_vec();
        // 9 tokens -> chunks [0..4],[4..8],[8..9]; tail len 1 < 25% of 4? 1 >= 1 so kept
        assert_eq!(out.len(), 3);
        assert_eq!(out[0]["targets"].as_ints().unwrap(), &[0, 1, 2, 3]);
        assert_eq!(out[2]["targets"].as_ints().unwrap(), &[8]);
    }

    #[test]
    fn span_corruption_invariants() {
        let v: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(16));
        let sc = SpanCorruption::new(v.clone());
        let tokens: Vec<i32> = (10..90).collect();
        let mut rng = Pcg64::new(1);
        let (inputs, targets) = sc.corrupt(&tokens, &mut rng);
        // All original tokens survive in inputs+targets (minus sentinels).
        let mut recovered: Vec<i32> = Vec::new();
        let mut from_inputs: Vec<i32> =
            inputs.iter().copied().filter(|&t| !v.is_sentinel(t)).collect();
        let from_targets: Vec<i32> =
            targets.iter().copied().filter(|&t| !v.is_sentinel(t)).collect();
        recovered.append(&mut from_inputs);
        recovered.extend(from_targets.iter());
        recovered.sort();
        let mut orig = tokens.clone();
        orig.sort();
        assert_eq!(recovered, orig);
        // ~15% of tokens are noise
        let noise_frac = from_targets.len() as f32 / tokens.len() as f32;
        assert!((0.05..=0.3).contains(&noise_frac), "{noise_frac}");
        // targets end with a sentinel
        assert!(v.is_sentinel(*targets.last().unwrap()));
        // sentinels in inputs appear in decreasing id order (k=0,1,2..)
        let sents: Vec<i32> =
            inputs.iter().copied().filter(|&t| v.is_sentinel(t)).collect();
        for w in sents.windows(2) {
            assert!(w[0] > w[1]);
        }
    }

    #[test]
    fn span_corruption_deterministic_per_seed() {
        let v: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(16));
        let sc = SpanCorruption::new(v);
        let ds1 = Dataset::from_vec(vec![ints_example(&[("targets", (0..50).collect())])]);
        let ds2 = Dataset::from_vec(vec![ints_example(&[("targets", (0..50).collect())])]);
        let a = sc.apply(ds1, &ctx()).collect_vec();
        let b = sc.apply(ds2, &ctx()).collect_vec();
        assert_eq!(a, b);
        let ds3 = Dataset::from_vec(vec![ints_example(&[("targets", (0..50).collect())])]);
        let c = sc.apply(ds3, &PipelineCtx { seed: 43 }).collect_vec();
        assert_ne!(a, c);
    }

    #[test]
    fn prefix_lm_splits() {
        let p = PrefixLm::default();
        let ds = Dataset::from_vec(vec![ints_example(&[("targets", (0..20).collect())])]);
        let out = p.apply(ds, &ctx()).collect_vec();
        let inp = out[0]["inputs"].as_ints().unwrap();
        let tgt = out[0]["targets"].as_ints().unwrap();
        assert!(!inp.is_empty() && !tgt.is_empty());
        let mut joined = inp.to_vec();
        joined.extend_from_slice(tgt);
        assert_eq!(joined, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn append_eos_and_trim_and_filter() {
        let p1 = AppendEos::new(&["targets"]);
        let p2 = TrimToLength { key: "targets".into(), max_len: 3 };
        let p3 = FilterShort { key: "targets".into(), min_len: 3 };
        let ds = Dataset::from_vec(vec![
            ints_example(&[("targets", vec![5, 6, 7, 8])]),
            ints_example(&[("targets", vec![9])]),
        ]);
        let out = p3
            .apply(p2.apply(p1.apply(ds, &ctx()), &ctx()), &ctx())
            .collect_vec();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0]["targets"].as_ints().unwrap(), &[5, 6, 7]);
    }

    #[test]
    fn random_partition_sums() {
        let mut rng = Pcg64::new(3);
        for _ in 0..100 {
            let total = 5 + rng.next_below(50) as usize;
            let parts = 1 + rng.next_below(5.min(total as u64)) as usize;
            let p = random_partition(total, parts, &mut rng);
            assert_eq!(p.iter().sum::<usize>(), total);
            assert_eq!(p.len(), parts);
            assert!(p.iter().all(|&x| x >= 1));
        }
    }
}
