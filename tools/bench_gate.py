#!/usr/bin/env python3
"""Bench trajectory snapshot + regression gate (stdlib only).

Reads the ``bench_results.jsonl`` that ``cargo bench`` appends (one JSON
object per measurement, see ``rust/src/bench/mod.rs::write_jsonl``),
writes a compact ``BENCH_<pr>.json`` snapshot for the committed
``benchmarks/`` trajectory, and gates on the PR-6 headline: on any
model-parallel mesh (model degree >= 2), block execution must not be
slower than gather execution of the same (model, mesh, strategy) case.

Usage (CI smoke job):

    python tools/bench_gate.py --input rust/bench_results.jsonl \
        --output benchmarks/BENCH_6.json [--tolerance 0.10]

Exit status is non-zero if the gate fails or if the input contains no
gather-vs-block pair to compare (so a silently-skipped comparison cannot
read as a pass). ``--tolerance`` is the allowed fractional shortfall —
quick-mode CI medians come from 2-5 iterations and are noisy; the
committed trajectory still records the exact ratios.
"""

import argparse
import json
import re
import sys

# "t5-nano-dec mesh=1x2 OneD block (2 steps)" — see bench_train_step.rs
TRAIN_ROW = re.compile(
    r"^(?P<model>\S+) mesh=(?P<data>\d+)x(?P<mdeg>\d+) "
    r"(?P<strategy>\w+) (?P<exec>gather|block) \(\d+ steps\)$"
)
TRAIN_GROUP = "train step (E16)"


def load_rows(path):
    rows = []
    with open(path, "r", encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows


def gate(rows, tolerance):
    """Return (pairs, failures) for the block-vs-gather comparison."""
    cases = {}
    for r in rows:
        if r.get("group") != TRAIN_GROUP:
            continue
        m = TRAIN_ROW.match(r.get("name", ""))
        if not m or int(m.group("mdeg")) < 2:
            continue
        key = (m.group("model"), m.group("data"), m.group("mdeg"),
               m.group("strategy"))
        cases.setdefault(key, {})[m.group("exec")] = r.get("throughput_per_s")
    pairs, failures = [], []
    for key, by_exec in sorted(cases.items()):
        if "gather" not in by_exec or "block" not in by_exec:
            continue
        g, b = by_exec["gather"], by_exec["block"]
        pair = {
            "model": key[0],
            "mesh": f"{key[1]}x{key[2]}",
            "strategy": key[3],
            "gather_tok_per_s": g,
            "block_tok_per_s": b,
            "block_over_gather": (b / g) if g else None,
        }
        pairs.append(pair)
        if g and b < g * (1.0 - tolerance):
            failures.append(
                f"{pair['model']} mesh={pair['mesh']} {pair['strategy']}: "
                f"block {b:.1f} tok/s < gather {g:.1f} tok/s "
                f"(ratio {b / g:.3f}, tolerance {tolerance:.2f})"
            )
    return pairs, failures


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--input", required=True, help="bench_results.jsonl path")
    ap.add_argument("--output", required=True, help="BENCH_<pr>.json path")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed fractional block-vs-gather shortfall")
    args = ap.parse_args()

    rows = load_rows(args.input)
    pairs, failures = gate(rows, args.tolerance)

    snapshot = {
        "schema": "t5x-bench-trajectory-v1",
        "source": args.input,
        "gate": {
            "rule": "block tok/s >= gather tok/s at model degree >= 2",
            "tolerance": args.tolerance,
            "pairs": pairs,
            "failures": failures,
        },
        "measurements": [
            {
                "group": r.get("group"),
                "name": r.get("name"),
                "median_s": r.get("median_s"),
                "throughput_per_s": r.get("throughput_per_s"),
                "throughput_unit": r.get("throughput_unit"),
            }
            for r in rows
        ],
    }
    with open(args.output, "w", encoding="utf-8") as f:
        json.dump(snapshot, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.output}: {len(rows)} measurements, "
          f"{len(pairs)} gather-vs-block pair(s)")

    if not pairs:
        print("gate: FAIL — no gather-vs-block pair found in "
              f"group '{TRAIN_GROUP}' (bench_train_step did not run?)",
              file=sys.stderr)
        return 1
    if failures:
        for f_ in failures:
            print(f"gate: FAIL — {f_}", file=sys.stderr)
        return 1
    for p in pairs:
        print(f"gate: ok — {p['model']} mesh={p['mesh']} {p['strategy']} "
              f"block/gather = {p['block_over_gather']:.3f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
